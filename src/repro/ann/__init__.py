"""JAX implementations of the paper's algorithm families (Table 2).

Importing this package registers every algorithm with the core registry.
"""

from repro.ann import distances, topk
from repro.ann.bruteforce import BruteForce
from repro.ann.ivf import IVF
from repro.ann.rpforest import RPForest
from repro.ann.lsh import HyperplaneLSH, E2LSH
from repro.ann.graph import KNNGraph
from repro.ann.hnsw import HNSW
from repro.ann.hamming import (BitsamplingAnnoy, BruteForceHamming,
                               MultiIndexHashing)
from repro.ann.sharded import ShardedBruteForce, ShardedIVF
# the mutable (delta-buffered) variants live outside this package but
# register through the same registries; a plain module import (no name
# access — repro.mutate imports back into this package) keeps the cycle
# resolvable from either entry point
import repro.mutate  # noqa: E402,F401

__all__ = [
    "distances", "topk", "BruteForce", "IVF", "RPForest", "HyperplaneLSH",
    "E2LSH", "KNNGraph", "HNSW", "BitsamplingAnnoy", "BruteForceHamming",
    "MultiIndexHashing", "ShardedBruteForce", "ShardedIVF",
]
