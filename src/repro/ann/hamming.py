"""Hamming-space algorithms (paper §4 Q4 and Figure 9).

  * ``BruteForceHamming``  — XOR + popcount over packed uint32 codes
                             (exact; uses the Pallas popcount kernel in
                             batch mode when enabled).
  * ``BitsamplingAnnoy``   — the paper's Hamming-aware Annoy variant:
                             tree nodes split on a *single sampled bit*
                             (Bitsampling LSH) instead of hyperplanes, with
                             popcount rerank.
  * ``MultiIndexHashing``  — Norouzi et al.'s MIH: codes are split into m
                             contiguous chunks; a query probes, per chunk,
                             all buckets within chunk-radius r.  With
                             r >= ceil((t+1)/m)-1 for threshold t this is
                             the exact algorithm; we expose r as the query
                             parameter (r large enough => exact, smaller =>
                             approximate), matching the paper's observation
                             that MIH parameters strongly affect QPS.

All three share the dense sorted-bucket machinery from the LSH module and
the functional (build -> IndexState, pure search) core.  Points are packed
uint32 words; bits = 32 * words.
"""

from __future__ import annotations

import itertools

import numpy as np

import jax
import jax.numpy as jnp

from repro.ann.functional import (FunctionalSpec, IndexState,
                                  prepare_queries, register_functional)
from repro.ann.lsh import bucket_lookup, sorted_buckets
from repro.ann.rpforest import forest_window, mask_dead_trees
from repro.ann.topk import chunked_topk, topk_smallest
from repro.core.interface import FunctionalANN
from repro.core.registry import register
from repro.kernels.rerank_topk import rerank_topk


def _popcount_matrix(Q, X):
    x = jax.lax.bitwise_xor(Q[:, None, :].astype(jnp.uint32),
                            X[None, :, :].astype(jnp.uint32))
    return jnp.sum(jax.lax.population_count(x), axis=-1).astype(jnp.int32)


def _hamming_rerank(state: IndexState, Q, cand, k: int):
    """Popcount rerank of a [b, C] candidate-id window through the shared
    streaming fold (:func:`repro.kernels.rerank_topk.rerank_topk`, XOR +
    popcount mode): identical to the one-shot ``topk_unique`` while peak
    memory stays O(b * (block + k)).  The ``rerank_kernel`` build flag
    swaps in the fused Pallas kernel (packed words DMA'd into VMEM
    scratch); ``rerank_block`` overrides the autotuned block."""
    return rerank_topk(
        Q, state["X"], cand, k=k, metric="hamming",
        block=state.static.get("rerank_block"),
        use_kernel=bool(state.static.get("rerank_kernel", False)))


# ------------------------------------------------------- brute force popcount
def bruteforce_build(X: np.ndarray, *, metric: str = "hamming",
                     backend: str = "jnp", streaming: bool = False,
                     corpus_block: int = 65536,
                     query_block: int = 4096) -> IndexState:
    X = np.asarray(X, np.uint32)
    return IndexState("BruteForceHamming", metric, {"X": jnp.asarray(X)}, {
        "n": int(X.shape[0]), "backend": backend,
        "streaming": bool(streaming), "corpus_block": int(corpus_block),
        "query_block": int(query_block),
    })


def bruteforce_search(state: IndexState, Q, *, k: int):
    Q = prepare_queries(Q, "hamming")
    k = min(k, state.stat("n"))
    if state.stat("backend") == "pallas":
        from repro.kernels.hamming import ops as hops

        return hops.hamming_topk(Q, state["X"], k=k)
    d = _popcount_matrix(Q, state["X"])
    return topk_smallest(d.astype(jnp.float32), k)


register_functional(FunctionalSpec(
    name="BruteForceHamming", build=bruteforce_build,
    search=bruteforce_search, supported_metrics=("hamming",),
))


@register("BruteForceHamming")
class BruteForceHamming(FunctionalANN):
    supported_metrics = ("hamming",)
    batch_block = 2048

    def __init__(self, metric: str, backend: str = "jnp",
                 streaming: bool = False, corpus_block: int = 65536,
                 query_block: int = 4096):
        super().__init__(metric, build_params=dict(
            backend=backend, streaming=bool(streaming),
            corpus_block=int(corpus_block), query_block=int(query_block)))
        self.backend = backend
        self.streaming = bool(streaming)
        self.corpus_block = int(corpus_block)
        self.query_block = int(query_block)
        suffix = ",streaming" if streaming else ""
        self.name = f"BruteForceHamming(backend={backend}{suffix})"
        self._dist_comps = 0

    def _sync_state(self):
        self._n = self._state.stat("n")

    def query(self, q, k):
        out = super().query(q, k)
        self._dist_comps += self._n
        return out

    def _batch_streaming(self, Qj, k):
        """Query-blocked corpus scan: per query block, stream corpus chunks
        through the fused Hamming top-k kernel and merge into a running
        (dist, id) accumulator — O(qblock * k) state, corpus never gathered
        whole."""
        X = self._state["X"]
        if self.backend == "pallas":
            from repro.kernels.hamming import ops as hops

            def corpus_chunk(Qb):
                def chunk(s, size):
                    v, i = hops.hamming_topk(Qb, X[s:s + size],
                                             k=min(k, size))
                    return v.astype(jnp.float32), i + s
                return chunk
        else:
            def corpus_chunk(Qb):
                def chunk(s, size):
                    d = _popcount_matrix(Qb, X[s:s + size])
                    ids = s + jnp.arange(size, dtype=jnp.int32)[None, :]
                    return (d.astype(jnp.float32),
                            jnp.broadcast_to(ids, d.shape))
                return chunk
        outs = []
        for qs in range(0, Qj.shape[0], self.query_block):
            Qb = Qj[qs:qs + self.query_block]
            _, ids = chunked_topk(self._n, k, self.corpus_block,
                                  corpus_chunk(Qb))
            outs.append(ids)
        return jnp.concatenate(outs, axis=0)

    def batch_query(self, Q, k):
        k = min(k, self._n)
        if self.streaming:
            Qj = jnp.asarray(np.asarray(Q, np.uint32))
            self._batch_results = jax.block_until_ready(
                self._batch_streaming(Qj, k))
            self._dist_comps += self._n * Q.shape[0]
        else:
            super().batch_query(Q, k)
            self._dist_comps += self._n * Q.shape[0]

    def get_additional(self):
        return {"dist_comps": self._dist_comps}


# ------------------------------------------------------- bitsampling forest
def bitsampling_build(X: np.ndarray, *, metric: str = "hamming",
                      n_trees: int = 10, leaf_size: int = 32, seed: int = 0,
                      streaming: bool = False, rerank_block=None,
                      rerank_kernel: bool = False) -> IndexState:
    """Annoy-style forest with single-bit splits (host build)."""
    X = np.asarray(X, np.uint32)
    n, w = X.shape
    bits = w * 32
    n_trees, leaf_size = int(n_trees), int(leaf_size)
    rng = np.random.default_rng(int(seed))
    max_depth = int(np.ceil(np.log2(
        max(2.0, n / max(1, leaf_size))))) + 6

    # Build: split on a random bit with the most even split among a few
    # tries (data-independent bitsampling, data-guided balance).
    trees_bits, trees_children, trees_leaves, roots = [], [], [], []
    host_bit = lambda pts, b: (pts[:, b // 32] >> (b % 32)) & 1  # noqa: E731

    for _ in range(n_trees):
        node_bits: list[int] = []
        children: list[list[int]] = []
        leaves: list[np.ndarray] = []

        def rec(ids: np.ndarray, depth: int) -> int:
            if len(ids) <= leaf_size or depth >= max_depth:
                leaves.append(ids)
                return -len(leaves)
            best_b, best_bal = None, -1.0
            for b in rng.integers(0, bits, size=4):
                side = host_bit(X[ids], int(b)).astype(bool)
                frac = side.mean()
                bal = min(frac, 1 - frac)
                if bal > best_bal:
                    best_bal, best_b = bal, int(b)
            side = host_bit(X[ids], best_b).astype(bool)
            if side.all() or (~side).all():
                side = rng.random(len(ids)) < 0.5
            node = len(node_bits)
            node_bits.append(best_b)
            children.append([0, 0])
            left = rec(ids[~side], depth + 1)
            right = rec(ids[side], depth + 1)
            children[node] = [left, right]
            return node

        roots.append(rec(np.arange(n), 0))
        trees_bits.append(node_bits)
        trees_children.append(children)
        trees_leaves.append(leaves)

    T = n_trees
    max_nodes = max(max(len(b), 1) for b in trees_bits)
    max_leaves = max(len(lv) for lv in trees_leaves)
    bits_arr = np.zeros((T, max_nodes), np.int32)
    child_arr = np.zeros((T, max_nodes, 2), np.int32)
    leaf_arr = np.full((T, max_leaves, leaf_size), -1, np.int32)
    for t in range(T):
        for i, (b, ch) in enumerate(zip(trees_bits[t], trees_children[t])):
            bits_arr[t, i], child_arr[t, i] = b, ch
        for li, ids in enumerate(trees_leaves[t]):
            leaf_arr[t, li, :len(ids)] = ids[:leaf_size]
    return IndexState("BitsamplingAnnoy", metric, {
        "X": jnp.asarray(X),
        "bits": jnp.asarray(bits_arr),
        "children": jnp.asarray(child_arr),
        "leaves": jnp.asarray(leaf_arr),
        "roots": jnp.asarray(np.asarray(roots, np.int32)),
    }, {"n": n, "w": w, "n_trees": T, "leaf_size": leaf_size,
        "depth": max_depth, "streaming": bool(streaming),
        "rerank_kernel": bool(rerank_kernel),
        "rerank_block": None if rerank_block is None else int(rerank_block)})


def _bitsampling_descend(state: IndexState, Q, cur):
    tree_ids = jnp.arange(cur.shape[1])[None, :]
    others = []
    for _ in range(state.stat("depth")):
        is_leaf = cur < 0
        node = jnp.maximum(cur, 0)
        b = state["bits"][tree_ids, node]                  # [bq, T]
        wsel = jnp.take_along_axis(
            Q.astype(jnp.uint32), (b // 32).astype(jnp.int32), axis=1)
        bit = (wsel >> (b % 32).astype(jnp.uint32)) & 1
        side = bit.astype(jnp.int32)
        nxt = state["children"][tree_ids, node, side]
        other = state["children"][tree_ids, node, 1 - side]
        others.append(jnp.where(is_leaf, cur, other))
        cur = jnp.where(is_leaf, cur, nxt)
    return cur, others


def bitsampling_search(state: IndexState, Q, *, k: int, probe: int = 1,
                       trees=None, max_probe=None, max_trees=None):
    """With ``max_probe`` (static) all cap leaves are descended and the
    candidates of alternates past the traced ``probe`` are masked to -1 —
    one trace serves every probe count up to the cap.  ``trees`` /
    ``max_trees`` is the same treatment along the tree axis (``None`` =
    all built trees): static it slices the forest, traced it masks dead
    trees' candidates — exact parity because the popcount rerank selects
    via ``topk_unique`` (canonical on the (id, dist) set)."""
    Q = prepare_queries(Q, "hamming")
    bq = Q.shape[0]
    T, trees = forest_window(state.stat("n_trees"), trees, max_trees)
    P = max(1, int(probe)) if max_probe is None else max(1, int(max_probe))
    start = jnp.broadcast_to(state["roots"][None, :T], (bq, T))
    leaf, others = _bitsampling_descend(state, Q, start)
    leaves = [leaf]
    # probe deepest not-taken branches (bit splits have no margins)
    for p in range(min(P - 1, len(others))):
        alt, _ = _bitsampling_descend(state, Q, others[-(p + 1)])
        leaves.append(alt)
    tree_ids = jnp.arange(T)[None, :]
    cands = []
    for j, lf in enumerate(leaves):
        lidx = jnp.maximum(-lf - 1, 0)
        pts = state["leaves"][tree_ids, lidx]
        pts = jnp.where((lf < 0)[..., None], pts, -1)
        pts = mask_dead_trees(pts, trees)               # traced trees knob
        if max_probe is not None and j > 0:
            # alternate j exists in the static path iff probe > j
            pts = jnp.where(jnp.asarray(probe) > j, pts, -1)
        cands.append(pts.reshape(bq, -1))
    cand = jnp.concatenate(cands, axis=1)
    return _hamming_rerank(state, Q, cand, k)


register_functional(FunctionalSpec(
    name="BitsamplingAnnoy", build=bitsampling_build,
    search=bitsampling_search,
    query_params=("probe", "trees", "max_probe", "max_trees"),
    query_defaults=(1, None, None, None),
    supported_metrics=("hamming",),
    traced_knobs=(("probe", "max_probe"), ("trees", "max_trees")),
))


@register("BitsamplingAnnoy")
class BitsamplingAnnoy(FunctionalANN):
    """Annoy with bit-sampling splits (paper Q4's 'A (Ham.)' variant)."""

    supported_metrics = ("hamming",)
    batch_block = 2048

    def __init__(self, metric: str, n_trees: int = 10, leaf_size: int = 32,
                 seed: int = 0, streaming: bool = False,
                 rerank_block=None, rerank_kernel: bool = False):
        super().__init__(metric, build_params=dict(
            n_trees=int(n_trees), leaf_size=int(leaf_size), seed=int(seed),
            streaming=bool(streaming), rerank_block=rerank_block,
            rerank_kernel=bool(rerank_kernel)))
        self.n_trees = int(n_trees)
        self.leaf_size = int(leaf_size)
        self.seed = int(seed)
        self.streaming = bool(streaming)
        self.rerank_block = rerank_block
        self.probe = 1
        self.name = f"BitsamplingAnnoy(T={n_trees},leaf={leaf_size})"
        self._dist_comps = 0

    def set_query_arguments(self, probe: int, trees=None) -> None:
        self.probe = max(1, int(probe))
        self._qparams["probe"] = self.probe
        self._qparams["trees"] = None if trees is None \
            else max(1, min(int(trees), self.n_trees))

    def query(self, q, k):
        out = super().query(q, k)
        self._dist_comps += self.n_trees * self.probe * self.leaf_size
        return out

    def batch_query(self, Q, k):
        super().batch_query(Q, k)
        self._dist_comps += Q.shape[0] * self.n_trees * self.probe * self.leaf_size

    def get_additional(self):
        return {"dist_comps": self._dist_comps}


# ------------------------------------------------------- multi-index hashing
def mih_build(X: np.ndarray, *, metric: str = "hamming",
              n_chunks: int = 16, cap: int = 128, seed: int = 0,
              streaming: bool = False, rerank_block=None,
              rerank_kernel: bool = False) -> IndexState:
    X = np.asarray(X, np.uint32)
    n, w = X.shape
    bits = w * 32
    m = int(n_chunks)
    chunk_bits = bits // m
    if chunk_bits > 30:
        raise ValueError("chunk too wide for int32 keys; use more chunks")
    # chunk substrings as int32 keys, one "table" per chunk
    keys = np.zeros((m, n), np.int32)
    unpacked = np.unpackbits(
        X.view(np.uint8), bitorder="little").reshape(n, bits)
    bit_weights = 2 ** np.arange(chunk_bits, dtype=np.int32)
    for c in range(m):
        seg = unpacked[:, c * chunk_bits:(c + 1) * chunk_bits]
        keys[c] = seg.astype(np.int64) @ bit_weights
    tkeys, tids = sorted_buckets(keys)
    return IndexState("MultiIndexHashing", metric, {
        "X": jnp.asarray(X), "keys": tkeys, "ids": tids,
        "bit_weights": jnp.asarray(bit_weights),
    }, {"n": n, "w": w, "n_chunks": m, "chunk_bits": chunk_bits,
        "cap": int(cap), "streaming": bool(streaming),
        "rerank_kernel": bool(rerank_kernel),
        "rerank_block": None if rerank_block is None else int(rerank_block)})


def _mih_query_chunks(state: IndexState, Q):
    """Q [b, w] uint32 -> chunk keys [b, m] int32 + bits [b, bits]."""
    bq = Q.shape[0]
    w = state.stat("w")
    chunk_bits = state.stat("chunk_bits")
    bits_total = w * 32
    words = Q.astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = ((words[:, :, None] >> shifts[None, None, :]) & 1)
    bits = bits.reshape(bq, bits_total).astype(jnp.int32)
    bw = state["bit_weights"]
    keys = [
        jnp.sum(bits[:, c * chunk_bits:(c + 1) * chunk_bits]
                * bw[None, :], axis=1)
        for c in range(state.stat("n_chunks"))
    ]
    return jnp.stack(keys, axis=1), bits


def mih_search(state: IndexState, Q, *, k: int, radius: int = 0,
               max_radius=None):
    """With ``max_radius`` (static) the probe-key tensor is enumerated at
    the cap and columns whose flip count exceeds the traced ``radius`` get
    key -1 (chunk keys are non-negative bit sums, so the lookup matches
    nothing) — one trace serves every radius up to the cap."""
    Q = prepare_queries(Q, "hamming")
    bq = Q.shape[0]
    m = state.stat("n_chunks")
    chunk_bits = state.stat("chunk_bits")
    R = int(radius) if max_radius is None else int(max_radius)
    base, bits = _mih_query_chunks(state, Q)               # [b, m]
    # probe keys: all chunk codes within hamming radius <= R
    flips: list[tuple[int, ...]] = [()]
    for r in range(1, R + 1):
        flips += list(itertools.combinations(range(chunk_bits), r))
    probe_keys = []
    bw = state["bit_weights"]
    for f in flips:
        delta = jnp.zeros((bq, m), jnp.int32)
        for bitpos in f:
            for c in range(m):
                qb = bits[:, c * chunk_bits + bitpos]
                delta = delta.at[:, c].add(
                    jnp.where(qb > 0, -bw[bitpos], bw[bitpos]))
        probe_keys.append(base + delta)
    qkeys = jnp.stack(probe_keys, axis=-1)                 # [b, m, P]
    if max_radius is not None:
        flip_r = jnp.asarray([len(f) for f in flips])      # [P]
        live = flip_r <= jnp.maximum(radius, 0)
        qkeys = jnp.where(live[None, None, :], qkeys, -1)
    cand = bucket_lookup(state["keys"], state["ids"], qkeys,
                         state.stat("cap"))
    return _hamming_rerank(state, Q, cand, k)


register_functional(FunctionalSpec(
    name="MultiIndexHashing", build=mih_build, search=mih_search,
    query_params=("radius", "max_radius"), query_defaults=(0, None),
    supported_metrics=("hamming",),
    traced_knobs=(("radius", "max_radius"),),
))


@register("MultiIndexHashing")
class MultiIndexHashing(FunctionalANN):
    supported_metrics = ("hamming",)
    batch_block = 1024

    def __init__(self, metric: str, n_chunks: int = 16, cap: int = 128,
                 seed: int = 0, streaming: bool = False,
                 rerank_block=None, rerank_kernel: bool = False):
        super().__init__(metric, build_params=dict(
            n_chunks=int(n_chunks), cap=int(cap), seed=int(seed),
            streaming=bool(streaming), rerank_block=rerank_block,
            rerank_kernel=bool(rerank_kernel)))
        self.n_chunks = int(n_chunks)
        self.cap = int(cap)
        self.streaming = bool(streaming)
        self.rerank_block = rerank_block
        self.radius = 0
        self.name = f"MIH(m={n_chunks},cap={cap})"
        self._dist_comps = 0

    def set_query_arguments(self, radius: int) -> None:
        self.radius = int(radius)
        self._qparams["radius"] = self.radius

    def query(self, q, k):
        out = super().query(q, k)
        self._dist_comps += self.n_chunks * self.cap
        return out

    def batch_query(self, Q, k):
        super().batch_query(Q, k)
        self._dist_comps += Q.shape[0] * self.n_chunks * self.cap

    def get_additional(self):
        return {"dist_comps": self._dist_comps}
