"""Hamming-space algorithms (paper §4 Q4 and Figure 9).

  * ``BruteForceHamming``  — XOR + popcount over packed uint32 codes
                             (exact; uses the Pallas popcount kernel in
                             batch mode when enabled).
  * ``BitsamplingAnnoy``   — the paper's Hamming-aware Annoy variant:
                             tree nodes split on a *single sampled bit*
                             (Bitsampling LSH) instead of hyperplanes, with
                             popcount rerank.
  * ``MultiIndexHashing``  — Norouzi et al.'s MIH: codes are split into m
                             contiguous chunks; a query probes, per chunk,
                             all buckets within chunk-radius r.  With
                             r >= ceil((t+1)/m)-1 for threshold t this is
                             the exact algorithm; we expose r as the query
                             parameter (r large enough => exact, smaller =>
                             approximate), matching the paper's observation
                             that MIH parameters strongly affect QPS.

All three share the dense sorted-bucket machinery from the LSH module.
Points are packed uint32 words; bits = 32 * words.
"""

from __future__ import annotations

import itertools
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.ann.lsh import _SortedBuckets
from repro.ann.topk import chunked_topk, topk_unique
from repro.core.interface import BaseANN
from repro.core.registry import register


def _popcount_matrix(Q, X):
    x = jax.lax.bitwise_xor(Q[:, None, :].astype(jnp.uint32),
                            X[None, :, :].astype(jnp.uint32))
    return jnp.sum(jax.lax.population_count(x), axis=-1).astype(jnp.int32)


def _rerank_chunked(Xj, Q, cand, k: int, block: int):
    """Streaming popcount rerank of a [b, C] candidate-id window: chunked
    scan with dedupe at every fold (``chunked_topk(unique=True)``), so the
    result is identical to the one-shot ``topk_unique`` while peak memory
    drops from O(b * C * w) to O(b * block * w)."""
    def chunk(s, size):
        c = cand[:, s:s + size]
        x = Xj[jnp.maximum(c, 0)]                          # [b, size, w]
        xor = jax.lax.bitwise_xor(x, Q[:, None, :].astype(jnp.uint32))
        d = jnp.sum(jax.lax.population_count(xor),
                    axis=-1).astype(jnp.float32)
        return jnp.where(c >= 0, d, jnp.inf), c

    return chunked_topk(cand.shape[1], k, block, chunk, unique=True)


@register("BruteForceHamming")
class BruteForceHamming(BaseANN):
    supported_metrics = ("hamming",)

    def __init__(self, metric: str, backend: str = "jnp",
                 streaming: bool = False, corpus_block: int = 65536,
                 query_block: int = 4096):
        super().__init__(metric)
        self.backend = backend
        self.streaming = bool(streaming)
        self.corpus_block = int(corpus_block)
        self.query_block = int(query_block)
        suffix = ",streaming" if streaming else ""
        self.name = f"BruteForceHamming(backend={backend}{suffix})"
        self._dist_comps = 0

    def fit(self, X: np.ndarray) -> None:
        self._X = jnp.asarray(np.asarray(X, np.uint32))
        self._n = X.shape[0]

        @partial(jax.jit, static_argnames=("k",))
        def _q(Q, k):
            d = _popcount_matrix(Q, self._X)
            neg, idx = jax.lax.top_k(-d, k)
            return -neg, idx

        self._jq = _q

    def _rebuild(self):
        @partial(jax.jit, static_argnames=("k",))
        def _q(Q, k):
            d = _popcount_matrix(Q, self._X)
            neg, idx = jax.lax.top_k(-d, k)
            return -neg, idx
        self._jq = _q

    def query(self, q, k):
        _, idx = self._jq(jnp.asarray(q, jnp.uint32)[None, :],
                          min(k, self._n))
        self._dist_comps += self._n
        return np.asarray(idx[0])

    def _batch_streaming(self, Qj, k):
        """Query-blocked corpus scan: per query block, stream corpus chunks
        through the fused Hamming top-k kernel and merge into a running
        (dist, id) accumulator — O(qblock * k) state, corpus never gathered
        whole."""
        if self.backend == "pallas":
            from repro.kernels.hamming import ops as hops

            def corpus_chunk(Qb):
                def chunk(s, size):
                    v, i = hops.hamming_topk(Qb, self._X[s:s + size],
                                             k=min(k, size))
                    return v.astype(jnp.float32), i + s
                return chunk
        else:
            def corpus_chunk(Qb):
                def chunk(s, size):
                    d = _popcount_matrix(Qb, self._X[s:s + size])
                    ids = s + jnp.arange(size, dtype=jnp.int32)[None, :]
                    return (d.astype(jnp.float32),
                            jnp.broadcast_to(ids, d.shape))
                return chunk
        outs = []
        for qs in range(0, Qj.shape[0], self.query_block):
            Qb = Qj[qs:qs + self.query_block]
            _, ids = chunked_topk(self._n, k, self.corpus_block,
                                  corpus_chunk(Qb))
            outs.append(ids)
        return jnp.concatenate(outs, axis=0)

    def batch_query(self, Q, k):
        k = min(k, self._n)
        Qj = jnp.asarray(np.asarray(Q, np.uint32))
        if self.streaming:
            self._batch_results = jax.block_until_ready(
                self._batch_streaming(Qj, k))
        elif self.backend == "pallas":
            from repro.kernels.hamming import ops as hops
            _, idx = hops.hamming_topk(Qj, self._X, k=k)
            self._batch_results = jax.block_until_ready(idx)
        else:
            outs = []
            for s in range(0, Q.shape[0], 2048):
                _, idx = self._jq(Qj[s:s + 2048], k)
                outs.append(idx)
            self._batch_results = jax.block_until_ready(
                jnp.concatenate(outs))
        self._dist_comps += self._n * Q.shape[0]

    def get_additional(self):
        return {"dist_comps": self._dist_comps}


@register("BitsamplingAnnoy")
class BitsamplingAnnoy(BaseANN):
    """Annoy with bit-sampling splits (paper Q4's 'A (Ham.)' variant)."""

    supported_metrics = ("hamming",)

    def __init__(self, metric: str, n_trees: int = 10, leaf_size: int = 32,
                 seed: int = 0, streaming: bool = False,
                 rerank_block: int = 4096):
        super().__init__(metric)
        self.n_trees = int(n_trees)
        self.leaf_size = int(leaf_size)
        self.seed = int(seed)
        self.streaming = bool(streaming)
        self.rerank_block = int(rerank_block)
        self.probe = 1
        self.name = f"BitsamplingAnnoy(T={n_trees},leaf={leaf_size})"
        self._dist_comps = 0

    def set_query_arguments(self, probe: int) -> None:
        self.probe = max(1, int(probe))

    def fit(self, X: np.ndarray) -> None:
        X = np.asarray(X, np.uint32)
        self._n, self._w = X.shape
        bits = self._w * 32
        self._Xj = jnp.asarray(X)
        rng = np.random.default_rng(self.seed)
        max_depth = int(np.ceil(np.log2(
            max(2.0, self._n / max(1, self.leaf_size))))) + 6

        # Build: split on a random bit with the most even split among a few
        # tries (data-independent bitsampling, data-guided balance).
        trees_bits, trees_children, trees_leaves, roots = [], [], [], []
        host_bit = lambda pts, b: (pts[:, b // 32] >> (b % 32)) & 1

        for _ in range(self.n_trees):
            node_bits: list[int] = []
            children: list[list[int]] = []
            leaves: list[np.ndarray] = []

            def rec(ids: np.ndarray, depth: int) -> int:
                if len(ids) <= self.leaf_size or depth >= max_depth:
                    leaves.append(ids)
                    return -len(leaves)
                best_b, best_bal = None, -1.0
                for b in rng.integers(0, bits, size=4):
                    side = host_bit(X[ids], int(b)).astype(bool)
                    frac = side.mean()
                    bal = min(frac, 1 - frac)
                    if bal > best_bal:
                        best_bal, best_b = bal, int(b)
                side = host_bit(X[ids], best_b).astype(bool)
                if side.all() or (~side).all():
                    side = rng.random(len(ids)) < 0.5
                node = len(node_bits)
                node_bits.append(best_b)
                children.append([0, 0])
                left = rec(ids[~side], depth + 1)
                right = rec(ids[side], depth + 1)
                children[node] = [left, right]
                return node

            roots.append(rec(np.arange(self._n), 0))
            trees_bits.append(node_bits)
            trees_children.append(children)
            trees_leaves.append(leaves)

        T = self.n_trees
        max_nodes = max(max(len(b), 1) for b in trees_bits)
        max_leaves = max(len(l) for l in trees_leaves)
        bits_arr = np.zeros((T, max_nodes), np.int32)
        child_arr = np.zeros((T, max_nodes, 2), np.int32)
        leaf_arr = np.full((T, max_leaves, self.leaf_size), -1, np.int32)
        for t in range(T):
            for i, (b, ch) in enumerate(zip(trees_bits[t], trees_children[t])):
                bits_arr[t, i], child_arr[t, i] = b, ch
            for l, ids in enumerate(trees_leaves[t]):
                leaf_arr[t, l, :len(ids)] = ids[:self.leaf_size]
        self._bits = jnp.asarray(bits_arr)
        self._children = jnp.asarray(child_arr)
        self._leaves = jnp.asarray(leaf_arr)
        self._roots = jnp.asarray(np.asarray(roots, np.int32))
        self._depth = max_depth
        self._rebuild()

    def _rebuild(self):
        self._jq = jax.jit(self._query_block, static_argnames=("k", "probe"))

    def _descend(self, Q, cur):
        T = self.n_trees
        tree_ids = jnp.arange(T)[None, :]
        others = []
        for _ in range(self._depth):
            is_leaf = cur < 0
            node = jnp.maximum(cur, 0)
            b = self._bits[tree_ids, node]                     # [bq, T]
            wsel = jnp.take_along_axis(
                Q.astype(jnp.uint32), (b // 32).astype(jnp.int32), axis=1)
            bit = (wsel >> (b % 32).astype(jnp.uint32)) & 1
            side = bit.astype(jnp.int32)
            nxt = self._children[tree_ids, node, side]
            other = self._children[tree_ids, node, 1 - side]
            others.append(jnp.where(is_leaf, cur, other))
            cur = jnp.where(is_leaf, cur, nxt)
        return cur, others

    def _query_block(self, Q, *, k: int, probe: int):
        bq = Q.shape[0]
        T = self.n_trees
        start = jnp.broadcast_to(self._roots[None, :], (bq, T))
        leaf, others = self._descend(Q, start)
        leaves = [leaf]
        # probe deepest not-taken branches (bit splits have no margins)
        for p in range(min(probe - 1, len(others))):
            alt, _ = self._descend(Q, others[-(p + 1)])
            leaves.append(alt)
        tree_ids = jnp.arange(T)[None, :]
        cands = []
        for lf in leaves:
            lidx = jnp.maximum(-lf - 1, 0)
            pts = self._leaves[tree_ids, lidx]
            pts = jnp.where((lf < 0)[..., None], pts, -1)
            cands.append(pts.reshape(bq, -1))
        cand = jnp.concatenate(cands, axis=1)
        if self.streaming and cand.shape[1] > self.rerank_block:
            return _rerank_chunked(self._Xj, Q, cand, min(k, cand.shape[1]),
                                   self.rerank_block)
        safe = jnp.maximum(cand, 0)
        x = self._Xj[safe]                                     # [bq, C, w]
        xor = jax.lax.bitwise_xor(x, Q[:, None, :].astype(jnp.uint32))
        d = jnp.sum(jax.lax.population_count(xor), axis=-1).astype(jnp.float32)
        d = jnp.where(cand >= 0, d, jnp.inf)
        return topk_unique(d, cand, min(k, cand.shape[1]))

    def query(self, q, k):
        _, ids = self._jq(jnp.asarray(q, jnp.uint32)[None, :], k=k,
                          probe=self.probe)
        self._dist_comps += self.n_trees * self.probe * self.leaf_size
        return np.asarray(ids[0])

    def batch_query(self, Q, k):
        outs = []
        Qj = jnp.asarray(np.asarray(Q, np.uint32))
        for s in range(0, Q.shape[0], 2048):
            _, ids = self._jq(Qj[s:s + 2048], k=k, probe=self.probe)
            outs.append(ids)
        self._batch_results = jax.block_until_ready(jnp.concatenate(outs))
        self._dist_comps += Q.shape[0] * self.n_trees * self.probe * self.leaf_size

    def get_additional(self):
        return {"dist_comps": self._dist_comps}


@register("MultiIndexHashing")
class MultiIndexHashing(BaseANN):
    supported_metrics = ("hamming",)

    def __init__(self, metric: str, n_chunks: int = 16, cap: int = 128,
                 seed: int = 0, streaming: bool = False,
                 rerank_block: int = 4096):
        super().__init__(metric)
        self.n_chunks = int(n_chunks)
        self.cap = int(cap)
        self.streaming = bool(streaming)
        self.rerank_block = int(rerank_block)
        self.radius = 0
        self.name = f"MIH(m={n_chunks},cap={cap})"
        self._dist_comps = 0

    def set_query_arguments(self, radius: int) -> None:
        self.radius = int(radius)

    def fit(self, X: np.ndarray) -> None:
        X = np.asarray(X, np.uint32)
        self._n, self._w = X.shape
        bits = self._w * 32
        m = self.n_chunks
        self._chunk_bits = bits // m
        if self._chunk_bits > 30:
            raise ValueError("chunk too wide for int32 keys; use more chunks")
        self._Xj = jnp.asarray(X)
        # chunk substrings as int64 keys, one "table" per chunk
        keys = np.zeros((m, self._n), np.int32)
        unpacked = np.unpackbits(
            X.view(np.uint8), bitorder="little").reshape(self._n, bits)
        self._bit_weights = 2 ** np.arange(self._chunk_bits, dtype=np.int32)
        for c in range(m):
            seg = unpacked[:, c * self._chunk_bits:(c + 1) * self._chunk_bits]
            keys[c] = seg.astype(np.int64) @ self._bit_weights
        self._buckets = _SortedBuckets(keys)
        self._rebuild()

    def _rebuild(self):
        self._jq = jax.jit(self._query_block, static_argnames=("k", "radius"))

    def _query_chunks(self, Q):
        """Q [b, w] uint32 -> chunk keys [b, m] int64 + bits [b, bits]."""
        bq = Q.shape[0]
        bits_total = self._w * 32
        words = Q.astype(jnp.uint32)
        shifts = jnp.arange(32, dtype=jnp.uint32)
        bits = ((words[:, :, None] >> shifts[None, None, :]) & 1)
        bits = bits.reshape(bq, bits_total).astype(jnp.int32)
        w = jnp.asarray(self._bit_weights)
        keys = [
            jnp.sum(bits[:, c * self._chunk_bits:(c + 1) * self._chunk_bits]
                    * w[None, :], axis=1)
            for c in range(self.n_chunks)
        ]
        return jnp.stack(keys, axis=1), bits

    def _query_block(self, Q, *, k: int, radius: int):
        bq = Q.shape[0]
        base, bits = self._query_chunks(Q)                 # [b, m]
        # probe keys: all chunk codes within hamming radius <= radius
        flips: list[tuple[int, ...]] = [()]
        for r in range(1, radius + 1):
            flips += list(itertools.combinations(range(self._chunk_bits), r))
        probe_keys = []
        w = jnp.asarray(self._bit_weights)
        for f in flips:
            delta = jnp.zeros((bq, self.n_chunks), jnp.int32)
            for bitpos in f:
                for c in range(self.n_chunks):
                    qb = bits[:, c * self._chunk_bits + bitpos]
                    delta = delta.at[:, c].add(
                        jnp.where(qb > 0, -w[bitpos], w[bitpos]))
            probe_keys.append(base + delta)
        qkeys = jnp.stack(probe_keys, axis=-1)             # [b, m, P]
        cand = self._buckets.lookup(qkeys, self.cap)
        if self.streaming and cand.shape[1] > self.rerank_block:
            return _rerank_chunked(self._Xj, Q, cand, min(k, cand.shape[1]),
                                   self.rerank_block)
        safe = jnp.maximum(cand, 0)
        x = self._Xj[safe]
        xor = jax.lax.bitwise_xor(x, Q[:, None, :].astype(jnp.uint32))
        d = jnp.sum(jax.lax.population_count(xor), axis=-1).astype(jnp.float32)
        d = jnp.where(cand >= 0, d, jnp.inf)
        return topk_unique(d, cand, min(k, cand.shape[1]))

    def query(self, q, k):
        _, ids = self._jq(jnp.asarray(q, jnp.uint32)[None, :], k=k,
                          radius=self.radius)
        self._dist_comps += self.n_chunks * self.cap
        return np.asarray(ids[0])

    def batch_query(self, Q, k):
        outs = []
        Qj = jnp.asarray(np.asarray(Q, np.uint32))
        for s in range(0, Q.shape[0], 1024):
            _, ids = self._jq(Qj[s:s + 1024], k=k, radius=self.radius)
            outs.append(ids)
        self._batch_results = jax.block_until_ready(jnp.concatenate(outs))
        self._dist_comps += Q.shape[0] * self.n_chunks * self.cap

    def get_additional(self):
        return {"dist_comps": self._dist_comps}
