"""Functional index core: immutable pytree ``IndexState`` + pure search.

The paper's ``BaseANN`` protocol (§3.1) is host-side and stateful: ``fit``
mutates the object, ``set_query_arguments`` reconfigures it between runs,
``query`` round-trips through numpy.  That is fine for the experiment loop
but caps everything the serving path needs — jit/vmap/shard composition,
micro-batched query streams, pytree checkpoints.

This module defines the device-side replacement.  Every algorithm is a pair
of pure functions over an immutable pytree:

    build(X, *, metric, **build_params) -> IndexState
    search(state, Q, *, k, **query_params) -> (dists [b, k], ids [b, k])

``IndexState`` is a registered pytree: its *arrays* are the leaves (device
buffers — corpus, centroids, hash tables, adjacency), its *static* dict
rides in the aux data (hashable hyperparameters — pad widths, tree depth,
backend).  ``search`` therefore composes with ``jax.jit`` / ``vmap`` /
``shard_map`` directly, and the same traced function serves every query
batch of a given shape.

Query-time knobs (``n_probes``, ``ef``, ``radius``, …) are explicit
arguments of ``search`` instead of ``set_query_arguments`` mutations.  Each
spec declares which are *static* (shape-affecting: retrace per value) and
which may be *traced* (runtime values under a static cap — e.g. IVF's
``n_probes`` with ``max_probes`` pinned, so one trace serves all
query-args groups).

The legacy class interface survives as a thin adapter
(:class:`repro.core.interface.FunctionalANN`); the serving engine
(:mod:`repro.serve.engine`) builds on this module directly.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax
import numpy as np

#: jit-trace counter keyed by algorithm name.  Every function jitted through
#: :func:`jit_search_fn` (so: ``FunctionalSpec.jit_search``, the
#: ``FunctionalANN`` adapter, the serve ``Engine`` and ``search_sweep``)
#: increments its spec's entry each time jax actually re-traces it.  Tests
#: reset it and assert "exactly one trace per knob sweep"; production code
#: never reads it.
TRACE_COUNTS: "collections.Counter[str]" = collections.Counter()


def _note_trace(name: str) -> None:
    TRACE_COUNTS[name] += 1


def jit_search_fn(fn: Callable, spec: "FunctionalSpec",
                  traced: Sequence[str] = ()) -> Callable:
    """jit ``fn`` with the spec's knobs pinned static, minus ``traced``.

    ``traced`` demotes spec-static query knobs to runtime values — legal
    only for knobs the spec declares a cap partner for (``traced_knobs``);
    the corresponding ``max_*`` cap must then be passed (static) at call
    time and bounds the in-kernel mask.  The returned callable counts its
    traces in :data:`TRACE_COUNTS` under the spec's name.
    """
    traced = tuple(traced)
    caps = dict(spec.traced_knobs)
    unknown = [t for t in traced if t not in caps]
    if unknown:
        raise ValueError(
            f"{spec.name}: knob(s) {unknown} have no traced-cap treatment; "
            f"traceable knobs: {sorted(caps)}")
    static = ("k",) + tuple(p for p in spec.static_params if p not in traced)

    @functools.wraps(fn)
    def probe(*args, **kwargs):
        _note_trace(spec.name)        # runs at trace time only
        return fn(*args, **kwargs)

    return jax.jit(probe, static_argnames=static)


def _freeze(value: Any) -> Any:
    """Make a static value hashable (lists -> tuples, recursively)."""
    if isinstance(value, (list, tuple)):
        return tuple(_freeze(v) for v in value)
    if isinstance(value, dict):
        return tuple(sorted((k, _freeze(v)) for k, v in value.items()))
    return value


@jax.tree_util.register_pytree_node_class
class IndexState:
    """Immutable device-resident index: array pytree + static hyperparams.

    ``arrays`` maps names to array leaves (or tuples of arrays, e.g. HNSW's
    per-level adjacency); ``static`` maps names to hashable metadata that
    determines trace identity.  Treat instances as frozen — derive new
    states with :meth:`replace`.
    """

    __slots__ = ("algo", "metric", "arrays", "static")

    def __init__(self, algo: str, metric: str,
                 arrays: Mapping[str, Any],
                 static: Optional[Mapping[str, Any]] = None):
        self.algo = algo
        self.metric = metric
        self.arrays = dict(arrays)
        self.static = {k: _freeze(v) for k, v in dict(static or {}).items()}

    # ------------------------------------------------------------- access
    def __getitem__(self, key: str):
        return self.arrays[key]

    def stat(self, key: str):
        return self.static[key]

    def replace(self, **arrays) -> "IndexState":
        merged = dict(self.arrays)
        merged.update(arrays)
        return IndexState(self.algo, self.metric, merged, self.static)

    def nbytes(self) -> int:
        leaves = jax.tree_util.tree_leaves(self.arrays)
        return int(sum(getattr(a, "nbytes", 0) for a in leaves))

    def device_put(self) -> "IndexState":
        """Move every array leaf onto the default device (jnp arrays)."""
        import jax.numpy as jnp

        return IndexState(
            self.algo, self.metric,
            jax.tree_util.tree_map(jnp.asarray, self.arrays), self.static)

    # ------------------------------------------------------------- pytree
    def tree_flatten(self):
        keys = tuple(sorted(self.arrays))
        children = tuple(self.arrays[k] for k in keys)
        aux = (self.algo, self.metric, keys,
               tuple(sorted(self.static.items())))
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        algo, metric, keys, static = aux
        return cls(algo, metric, dict(zip(keys, children)), dict(static))

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"IndexState({self.algo!r}, {self.metric!r}, "
                f"arrays={sorted(self.arrays)}, "
                f"{self.nbytes() / 1024:.0f} kB)")


@dataclasses.dataclass(frozen=True)
class FunctionalSpec:
    """One algorithm's functional API: pure ``build`` + pure ``search``.

    ``query_params``        positional order of query-time knobs (matches the
                            legacy ``set_query_arguments`` convention).
    ``query_defaults``      default value per knob.
    ``static_query_params`` knobs that must be trace-time constants
                            (shape-affecting).  Knobs not listed here may be
                            traced runtime values.
    ``traced_knobs``        (knob, cap) pairs: knobs that MAY be demoted to
                            traced runtime values once their static ``max_*``
                            cap partner is pinned — the search then sizes its
                            candidate window by the cap and masks work past
                            the knob value in-kernel, so ONE trace serves
                            every knob value up to the cap (exact parity with
                            the static path).  Two traced-mode caveats: a
                            knob value ABOVE the cap is silently clamped to
                            it (shapes are fixed at trace time; reject
                            over-cap requests host-side like serve.Engine
                            does), and the output is min(k, cap) wide — for
                            knob values where the static path would return
                            fewer than k columns, the tail is (+inf, -1)
                            padding instead of a narrower array.
    """

    name: str
    build: Callable[..., IndexState]
    search: Callable[..., Tuple[Any, Any]]
    query_params: Tuple[str, ...] = ()
    query_defaults: Tuple[Any, ...] = ()
    static_query_params: Optional[Tuple[str, ...]] = None
    supported_metrics: Tuple[str, ...] = ("euclidean", "angular")
    traced_knobs: Tuple[Tuple[str, str], ...] = ()

    @property
    def static_params(self) -> Tuple[str, ...]:
        if self.static_query_params is None:
            return self.query_params
        return self.static_query_params

    def default_query_params(self) -> Dict[str, Any]:
        return dict(zip(self.query_params, self.query_defaults))

    def cap_for(self, knob: str) -> str:
        """The static cap partner of a traced-capable knob."""
        caps = dict(self.traced_knobs)
        if knob not in caps:
            raise KeyError(
                f"{self.name} has no traced-cap treatment for knob "
                f"{knob!r}; traced knobs: {sorted(caps)}")
        return caps[knob]

    def jit_search(self, traced: Sequence[str] = ()):
        """The search function jitted with k + static knobs pinned.

        ``traced`` names knobs to demote to runtime values (their ``max_*``
        caps must then be passed as static arguments) — see
        :func:`jit_search_fn`.
        """
        return jit_search_fn(self.search, self, traced)


FUNCTIONAL: Dict[str, FunctionalSpec] = {}


def _metric_checked_build(spec: FunctionalSpec) -> Callable[..., IndexState]:
    """Wrap a build fn so unsupported metrics fail fast (the functional
    analogue of the BaseANN constructor's metric validation)."""
    import functools
    import inspect

    original = spec.build
    default = inspect.signature(original).parameters["metric"].default

    @functools.wraps(original)
    def build(X, *, metric=default, **params) -> IndexState:
        if metric not in spec.supported_metrics:
            raise ValueError(
                f"{spec.name} does not support metric {metric!r} "
                f"(supported: {list(spec.supported_metrics)})")
        return original(X, metric=metric, **params)

    return build


def register_functional(spec: FunctionalSpec) -> FunctionalSpec:
    if spec.name in FUNCTIONAL:
        raise ValueError(f"duplicate functional registration: {spec.name}")
    spec = dataclasses.replace(spec, build=_metric_checked_build(spec))
    FUNCTIONAL[spec.name] = spec
    return spec


def get_functional(name: str) -> FunctionalSpec:
    """Resolve a functional spec, importing the algorithm package first."""
    import importlib

    importlib.import_module("repro.ann")
    spec = FUNCTIONAL.get(name)
    if spec is None:
        raise KeyError(
            f"no functional spec for {name!r}; known: {sorted(FUNCTIONAL)}")
    return spec


def available_functional() -> Dict[str, FunctionalSpec]:
    import importlib

    importlib.import_module("repro.ann")
    return dict(FUNCTIONAL)


# --------------------------------------------------------------------------
# retrace-free knob sweeps (multi-knob cartesian grids)
# --------------------------------------------------------------------------

# Bounded FIFO cache of jitted sweep executables, keyed by everything that
# determines trace identity EXCEPT the knob values themselves — so re-running
# a sweep with different values (same grid length) reuses the same trace.
_SWEEP_FNS: Dict[Any, Callable] = {}
_SWEEP_FNS_MAX = 64


def _sweep_searcher(spec: "FunctionalSpec", knobs: Tuple[str, ...],
                    caps: Tuple[Tuple[str, int], ...], k: int,
                    fixed_items: tuple) -> Callable:
    key = (spec.name, knobs, caps, k, fixed_items)
    fn = _SWEEP_FNS.get(key)
    if fn is None:
        if len(_SWEEP_FNS) >= _SWEEP_FNS_MAX:
            _SWEEP_FNS.pop(next(iter(_SWEEP_FNS)))
        fixed = dict(fixed_items)
        cap_params = dict(caps)

        def one(state, Q, vs):
            _note_trace(spec.name)    # runs at trace time only
            params = dict(zip(knobs, vs))
            params.update(cap_params)
            params.update(fixed)
            return spec.search(state, Q, k=k, **params)

        fn = _SWEEP_FNS[key] = jax.jit(
            jax.vmap(one, in_axes=(None, None, 0)))
    return fn


def grid_combos(knob_grid: Mapping[str, Sequence]) -> list:
    """Expand a knob grid into its cartesian combinations.

    Returns a list of ``{knob: value}`` dicts in row order of
    :func:`search_sweep` — knobs iterate in ``knob_grid`` insertion order,
    the LAST knob varying fastest (C order, like ``itertools.product``).
    """
    import itertools

    names = list(knob_grid)
    axes = [list(knob_grid[n]) for n in names]
    if any(len(a) == 0 for a in axes):
        raise ValueError("every knob in knob_grid needs at least one value")
    return [dict(zip(names, combo)) for combo in itertools.product(*axes)]


def search_sweep_points(state: IndexState, Q, *, k: int,
                        points: Sequence[Mapping[str, Any]],
                        **query_params) -> Tuple[Any, Any]:
    """Evaluate explicit knob combinations in ONE trace: vmap over points.

    ``points`` is a non-empty sequence of ``{knob: value}`` dicts, all with
    the SAME set of traced-capable knobs (see the spec's ``traced_knobs``);
    they need not form a full cartesian grid — the experiment loop feeds
    its literal ``query-args`` groups through here.  Each knob's static
    ``max_*`` cap is pinned to the max over points unless passed explicitly
    in ``query_params``.  Returns ``(dists [S, b, kk], ids [S, b, kk])``
    with ``S = len(points)`` — row ``i`` is exactly what the static path
    returns for ``points[i]``.

    The compiled executable is cached on (algo, knobs, caps, k, other
    params), so repeated sweeps — including sweeps over *different* values
    of the same grid size — never retrace; a sweep is one device call
    instead of one compile + one call per combination.
    """
    import jax.numpy as jnp

    spec = get_functional(state.algo)
    points = list(points)
    if not points:
        raise ValueError("points must be a non-empty sequence of knob dicts")
    knobs = tuple(points[0])
    if not knobs:
        raise ValueError("each point must set at least one knob")
    for pt in points:
        if tuple(pt) != knobs:
            raise ValueError(
                f"every point must set the same knobs; got {sorted(knobs)} "
                f"and {sorted(pt)}")
    fixed = dict(query_params)
    caps = []
    for knob in knobs:
        cap_name = spec.cap_for(knob)
        if knob in fixed:
            raise ValueError(
                f"{knob!r} appears in both the sweep grid and "
                f"query_params; its value comes from the grid — drop it "
                f"from query_params")
        vmax = max(int(pt[knob]) for pt in points)
        cap = fixed.pop(cap_name, None)
        if cap is None:
            cap = vmax
        elif vmax > int(cap):
            raise ValueError(
                f"sweep value {knob}={vmax} exceeds {cap_name}={int(cap)}; "
                f"the in-kernel mask would clamp it and mislabel the row — "
                f"raise the cap or drop the value")
        caps.append((cap_name, int(cap)))
    # [S, n_knobs] int32: row i carries point i's knob values, vmapped axis 0
    values = jnp.asarray(
        np.asarray([[int(pt[knob]) for knob in knobs] for pt in points],
                   dtype=np.int32))
    fn = _sweep_searcher(spec, knobs, tuple(caps), int(k),
                         tuple(sorted(fixed.items())))
    return fn(state, Q, values)


def search_sweep(state: IndexState, Q, *, k: int,
                 knob_grid: Mapping[str, Sequence],
                 **query_params) -> Tuple[Any, Any]:
    """Evaluate a cartesian query-knob grid in ONE trace: vmap over combos.

    ``knob_grid`` maps one or more traced-capable knobs (the spec's
    ``traced_knobs`` — all of them may be swept together) to the values to
    sweep; the full cartesian product is evaluated in a single device call.
    Each knob's static ``max_*`` cap is pinned to ``max(values)`` unless
    passed explicitly in ``query_params``.  Returns ``(dists [S, b, kk],
    ids [S, b, kk])`` with ``S = prod(len(values_i))`` — row ``i`` is
    exactly what the static path returns for combination ``i`` in
    :func:`grid_combos` order (knobs in ``knob_grid`` insertion order, the
    last knob varying fastest).

    The compiled executable is cached on (algo, knobs, caps, k, other
    params), so repeated sweeps — including sweeps over *different* values
    of the same grid shape — never retrace; a whole grid is one device
    call instead of one compile + one call per combination.
    """
    return search_sweep_points(state, Q, k=k, points=grid_combos(knob_grid),
                               **query_params)


# --------------------------------------------------------------------------
# shared build helpers
# --------------------------------------------------------------------------

def prepare_points(X: np.ndarray, metric: str) -> np.ndarray:
    """Host-side canonicalisation: float32 (unit-normalised for angular),
    packed uint32 words for hamming."""
    if metric == "hamming":
        return np.asarray(X, np.uint32)
    X = np.asarray(X, np.float32)
    if metric == "angular":
        X = X / np.maximum(np.linalg.norm(X, axis=1, keepdims=True), 1e-12)
    return X


def prepare_queries(Q, metric: str):
    """Traced-side canonicalisation of a query batch (jit-friendly)."""
    import jax.numpy as jnp

    if metric == "hamming":
        return jnp.asarray(Q, jnp.uint32)
    Q = jnp.asarray(Q).astype(jnp.float32)
    if metric == "angular":
        Q = Q / jnp.maximum(jnp.linalg.norm(Q, axis=1, keepdims=True), 1e-12)
    return Q
