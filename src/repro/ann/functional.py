"""Functional index core: immutable pytree ``IndexState`` + pure search.

The paper's ``BaseANN`` protocol (§3.1) is host-side and stateful: ``fit``
mutates the object, ``set_query_arguments`` reconfigures it between runs,
``query`` round-trips through numpy.  That is fine for the experiment loop
but caps everything the serving path needs — jit/vmap/shard composition,
micro-batched query streams, pytree checkpoints.

This module defines the device-side replacement.  Every algorithm is a pair
of pure functions over an immutable pytree:

    build(X, *, metric, **build_params) -> IndexState
    search(state, Q, *, k, **query_params) -> (dists [b, k], ids [b, k])

``IndexState`` is a registered pytree: its *arrays* are the leaves (device
buffers — corpus, centroids, hash tables, adjacency), its *static* dict
rides in the aux data (hashable hyperparameters — pad widths, tree depth,
backend).  ``search`` therefore composes with ``jax.jit`` / ``vmap`` /
``shard_map`` directly, and the same traced function serves every query
batch of a given shape.

Query-time knobs (``n_probes``, ``ef``, ``radius``, …) are explicit
arguments of ``search`` instead of ``set_query_arguments`` mutations.  Each
spec declares which are *static* (shape-affecting: retrace per value) and
which may be *traced* (runtime values under a static cap — e.g. IVF's
``n_probes`` with ``max_probes`` pinned, so one trace serves all
query-args groups).

The legacy class interface survives as a thin adapter
(:class:`repro.core.interface.FunctionalANN`); the serving engine
(:mod:`repro.serve.engine`) builds on this module directly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Optional, Sequence, Tuple

import jax
import numpy as np


def _freeze(value: Any) -> Any:
    """Make a static value hashable (lists -> tuples, recursively)."""
    if isinstance(value, (list, tuple)):
        return tuple(_freeze(v) for v in value)
    if isinstance(value, dict):
        return tuple(sorted((k, _freeze(v)) for k, v in value.items()))
    return value


@jax.tree_util.register_pytree_node_class
class IndexState:
    """Immutable device-resident index: array pytree + static hyperparams.

    ``arrays`` maps names to array leaves (or tuples of arrays, e.g. HNSW's
    per-level adjacency); ``static`` maps names to hashable metadata that
    determines trace identity.  Treat instances as frozen — derive new
    states with :meth:`replace`.
    """

    __slots__ = ("algo", "metric", "arrays", "static")

    def __init__(self, algo: str, metric: str,
                 arrays: Mapping[str, Any],
                 static: Optional[Mapping[str, Any]] = None):
        self.algo = algo
        self.metric = metric
        self.arrays = dict(arrays)
        self.static = {k: _freeze(v) for k, v in dict(static or {}).items()}

    # ------------------------------------------------------------- access
    def __getitem__(self, key: str):
        return self.arrays[key]

    def stat(self, key: str):
        return self.static[key]

    def replace(self, **arrays) -> "IndexState":
        merged = dict(self.arrays)
        merged.update(arrays)
        return IndexState(self.algo, self.metric, merged, self.static)

    def nbytes(self) -> int:
        leaves = jax.tree_util.tree_leaves(self.arrays)
        return int(sum(getattr(a, "nbytes", 0) for a in leaves))

    def device_put(self) -> "IndexState":
        """Move every array leaf onto the default device (jnp arrays)."""
        import jax.numpy as jnp

        return IndexState(
            self.algo, self.metric,
            jax.tree_util.tree_map(jnp.asarray, self.arrays), self.static)

    # ------------------------------------------------------------- pytree
    def tree_flatten(self):
        keys = tuple(sorted(self.arrays))
        children = tuple(self.arrays[k] for k in keys)
        aux = (self.algo, self.metric, keys,
               tuple(sorted(self.static.items())))
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        algo, metric, keys, static = aux
        return cls(algo, metric, dict(zip(keys, children)), dict(static))

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"IndexState({self.algo!r}, {self.metric!r}, "
                f"arrays={sorted(self.arrays)}, "
                f"{self.nbytes() / 1024:.0f} kB)")


@dataclasses.dataclass(frozen=True)
class FunctionalSpec:
    """One algorithm's functional API: pure ``build`` + pure ``search``.

    ``query_params``        positional order of query-time knobs (matches the
                            legacy ``set_query_arguments`` convention).
    ``query_defaults``      default value per knob.
    ``static_query_params`` knobs that must be trace-time constants
                            (shape-affecting).  Knobs not listed here may be
                            traced runtime values.
    """

    name: str
    build: Callable[..., IndexState]
    search: Callable[..., Tuple[Any, Any]]
    query_params: Tuple[str, ...] = ()
    query_defaults: Tuple[Any, ...] = ()
    static_query_params: Optional[Tuple[str, ...]] = None
    supported_metrics: Tuple[str, ...] = ("euclidean", "angular")

    @property
    def static_params(self) -> Tuple[str, ...]:
        if self.static_query_params is None:
            return self.query_params
        return self.static_query_params

    def default_query_params(self) -> Dict[str, Any]:
        return dict(zip(self.query_params, self.query_defaults))

    def jit_search(self):
        """The search function jitted with k + static knobs pinned."""
        static = ("k",) + tuple(self.static_params)
        return jax.jit(self.search, static_argnames=static)


FUNCTIONAL: Dict[str, FunctionalSpec] = {}


def _metric_checked_build(spec: FunctionalSpec) -> Callable[..., IndexState]:
    """Wrap a build fn so unsupported metrics fail fast (the functional
    analogue of the BaseANN constructor's metric validation)."""
    import functools
    import inspect

    original = spec.build
    default = inspect.signature(original).parameters["metric"].default

    @functools.wraps(original)
    def build(X, *, metric=default, **params) -> IndexState:
        if metric not in spec.supported_metrics:
            raise ValueError(
                f"{spec.name} does not support metric {metric!r} "
                f"(supported: {list(spec.supported_metrics)})")
        return original(X, metric=metric, **params)

    return build


def register_functional(spec: FunctionalSpec) -> FunctionalSpec:
    if spec.name in FUNCTIONAL:
        raise ValueError(f"duplicate functional registration: {spec.name}")
    spec = dataclasses.replace(spec, build=_metric_checked_build(spec))
    FUNCTIONAL[spec.name] = spec
    return spec


def get_functional(name: str) -> FunctionalSpec:
    """Resolve a functional spec, importing the algorithm package first."""
    import importlib

    importlib.import_module("repro.ann")
    spec = FUNCTIONAL.get(name)
    if spec is None:
        raise KeyError(
            f"no functional spec for {name!r}; known: {sorted(FUNCTIONAL)}")
    return spec


def available_functional() -> Dict[str, FunctionalSpec]:
    import importlib

    importlib.import_module("repro.ann")
    return dict(FUNCTIONAL)


# --------------------------------------------------------------------------
# shared build helpers
# --------------------------------------------------------------------------

def prepare_points(X: np.ndarray, metric: str) -> np.ndarray:
    """Host-side canonicalisation: float32 (unit-normalised for angular),
    packed uint32 words for hamming."""
    if metric == "hamming":
        return np.asarray(X, np.uint32)
    X = np.asarray(X, np.float32)
    if metric == "angular":
        X = X / np.maximum(np.linalg.norm(X, axis=1, keepdims=True), 1e-12)
    return X


def prepare_queries(Q, metric: str):
    """Traced-side canonicalisation of a query batch (jit-friendly)."""
    import jax.numpy as jnp

    if metric == "hamming":
        return jnp.asarray(Q, jnp.uint32)
    Q = jnp.asarray(Q).astype(jnp.float32)
    if metric == "angular":
        Q = Q / jnp.maximum(jnp.linalg.norm(Q, axis=1, keepdims=True), 1e-12)
    return Q
