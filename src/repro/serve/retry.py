"""Retry/backoff policy for transient serving faults.

The async pump applies a :class:`RetryPolicy` to every micro-batch whose
device call raises a :class:`~repro.serve.errors.TransientFault` (e.g. an
injected or real shard crash): the batch is retried up to
``max_attempts`` with exponential backoff and *deterministic* jitter —
the jitter draw is keyed by ``(seed, ticket, attempt)``, so a replayed
fault schedule produces a bit-identical retry timeline instead of a
flaky one.

The budget is deadline-aware: a retry is only taken if at least one live
request in the batch could still meet its deadline after the backoff
sleep; otherwise the batch fails immediately with
:class:`~repro.serve.errors.RetriesExhausted` (wrapping the last cause)
rather than burning the tail of every deadline on doomed attempts.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.serve.errors import TransientFault


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Max attempts + exponential backoff with deterministic jitter.

    ``max_attempts`` counts the first try: 3 means one try plus up to two
    retries; 1 disables retrying.  Backoff before retry ``a`` (1-based)
    is ``min(base_ms * multiplier**(a-1), max_ms)``, jittered uniformly
    by ``±jitter`` (fraction), with the draw keyed by
    ``(seed, token, a)``.
    """

    max_attempts: int = 3
    base_ms: float = 1.0
    multiplier: float = 2.0
    max_ms: float = 50.0
    jitter: float = 0.5
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got "
                             f"{self.max_attempts}")
        if self.base_ms < 0 or self.max_ms < 0:
            raise ValueError("backoff times must be >= 0")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError(f"jitter={self.jitter} is a fraction in [0, 1]")

    def backoff_s(self, attempt: int, token: int = 0) -> float:
        """Seconds to sleep before retry ``attempt`` (1-based), for the
        request identified by ``token`` (the batch head's ticket seq)."""
        base = min(self.base_ms * self.multiplier ** (attempt - 1),
                   self.max_ms) / 1e3
        if self.jitter <= 0.0 or base <= 0.0:
            return base
        u = float(np.random.default_rng(
            (self.seed, int(token), int(attempt))).random())
        return base * (1.0 + self.jitter * (2.0 * u - 1.0))

    def retryable(self, exc: BaseException) -> bool:
        return isinstance(exc, TransientFault)

    @classmethod
    def from_spec(cls, spec: str) -> "RetryPolicy":
        """Parse the CLI form: ``"attempts=4,base_ms=2,jitter=0.5"``."""
        kwargs = {}
        names = {"attempts": "max_attempts", "max_attempts": "max_attempts",
                 "base_ms": "base_ms", "multiplier": "multiplier",
                 "max_ms": "max_ms", "jitter": "jitter", "seed": "seed"}
        for item in filter(None, (p.strip() for p in spec.split(","))):
            key, sep, value = item.partition("=")
            if not sep or key.strip() not in names:
                raise ValueError(f"unknown retry knob {item!r}; known: "
                                 f"{sorted(set(names))}")
            field = names[key.strip()]
            kwargs[field] = (int(value) if field in ("max_attempts", "seed")
                             else float(value))
        return cls(**kwargs)
