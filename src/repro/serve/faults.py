"""Deterministic fault injection for the serving stack.

The ANN-Benchmarks harness isolates every algorithm run so a crash can
never take down the tool; this module is the serving tier's equivalent
discipline made testable: every failure mode the stack must survive can
be *scheduled* — deterministically, from a seed — and driven through the
same production code paths a real fault would take.  No monkeypatching:
the production modules call the site hooks below explicitly, and every
hook is a no-op unless a :class:`FaultPlan` is installed.

Fault kinds and their hook sites:

  ==============  ====================================================
  ``shard_drop``  ``dist/shard_state.sharded_search`` (direct calls) and
                  ``serve.Engine._run_padded`` (the jitted serving path):
                  per (call, shard) — the shard's local results are
                  masked to the merge tree's existing ``(+inf, -1)``
                  sentinel channel, so the merge stays exact over the
                  survivors and the response is *degraded* (``partial``
                  with ``coverage < 1``), never failed.
  ``shard_raise`` same sites, per call — the whole sharded search raises
                  :class:`~repro.serve.errors.ShardFault` (transient;
                  the pump's RetryPolicy retries it).
  ``slow_shard``  same sites, per call — a host-side latency spike of
                  ``slow_ms`` before dispatch (creates deadline
                  pressure; the SPMD dispatch is synchronous, so one
                  slow shard slows its whole call).
  ``pump_death``  ``AsyncEngine`` pump loop, per served batch — raises
                  :class:`PumpFault` *outside* the per-batch handler,
                  simulating a bug escaping into the pump thread; the
                  supervisor must fail all outstanding tickets with
                  ``EngineDegraded`` instead of hanging them.
  ``compact_fault``  ``mutate/delta.compact``, per compaction — the
                  rebuild raises
                  :class:`~repro.serve.errors.CompactionError` before
                  any new state exists (serving state untouched).
  ``ckpt_truncate``  ``serve/checkpoint.save``, per save — the written
                  file is truncated to ``truncate_frac`` of its bytes,
                  so the *load* hardening (typed ``CheckpointError``)
                  is exercised end to end.
  ==============  ====================================================

Determinism: each site keeps an event counter, and the decision for
event ``n`` is a pure function of ``(seed, site, n[, shard])`` via a
counter-keyed PRNG — a plan replays identically given the same event
order (single pump thread + one client loop, the chaos-bench shape).
Tests that need exact placement use the explicit ``*_at=`` event-index
tuples instead of rates.

Install a plan process-wide with :func:`install`/:func:`clear`, or scope
it with the :func:`injected` context manager::

    with faults.injected(faults.FaultPlan(seed=7, shard_drop=0.1)):
        srv.submit(q).result()          # may come back partial

``FaultPlan.from_spec("seed=7,shard_drop=0.1,slow_ms=5")`` parses the
CLI/bench form (``--faults`` in ``repro.launch.serve``).
"""

from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from typing import Optional, Tuple

import numpy as np

from repro.serve.errors import CompactionError, ShardFault

#: hook sites, in the order their codes key the per-event PRNG.
SITES = ("shard_drop", "shard_raise", "slow_shard", "pump_death",
         "compact_fault", "ckpt_truncate")
_SITE_CODE = {s: i for i, s in enumerate(SITES)}

_RATES = ("shard_drop", "shard_raise", "slow_shard", "pump_death",
          "compact_fault", "ckpt_truncate")


class PumpFault(RuntimeError):
    """Injected pump-thread crash — deliberately NOT a ServeError: it
    models an unexpected bug escaping the per-batch handler, and the
    supervisor is what must translate it into typed ticket failures."""


class FaultPlan:
    """One seeded, deterministic schedule of injected faults.

    Rate knobs (``shard_drop=0.1`` …) are per-event probabilities in
    ``[0, 1]``; the ``*_at=`` tuples pin faults to exact event indices
    (``shard_drop_at`` takes ``(event, shard)`` pairs).  A plan is
    reusable but stateful (event counters) — build a fresh one per run
    for reproducible schedules.
    """

    def __init__(self, seed: int = 0, *,
                 shard_drop: float = 0.0,
                 shard_raise: float = 0.0,
                 slow_shard: float = 0.0,
                 slow_ms: float = 20.0,
                 pump_death: float = 0.0,
                 compact_fault: float = 0.0,
                 ckpt_truncate: float = 0.0,
                 truncate_frac: float = 0.5,
                 shard_drop_at: Tuple[Tuple[int, int], ...] = (),
                 shard_raise_at: Tuple[int, ...] = (),
                 slow_shard_at: Tuple[int, ...] = (),
                 pump_death_at: Tuple[int, ...] = (),
                 compact_fault_at: Tuple[int, ...] = (),
                 ckpt_truncate_at: Tuple[int, ...] = ()):
        self.seed = int(seed)
        self.shard_drop = float(shard_drop)
        self.shard_raise = float(shard_raise)
        self.slow_shard = float(slow_shard)
        self.slow_ms = float(slow_ms)
        self.pump_death = float(pump_death)
        self.compact_fault = float(compact_fault)
        self.ckpt_truncate = float(ckpt_truncate)
        self.truncate_frac = float(truncate_frac)
        for name in _RATES:
            rate = getattr(self, name)
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"{name}={rate} is not a rate in [0, 1]")
        if not 0.0 < self.truncate_frac < 1.0:
            raise ValueError(f"truncate_frac={truncate_frac} must be in "
                             f"(0, 1) — 0 keeps nothing, 1 injects nothing")
        self.shard_drop_at = frozenset(
            (int(e), int(s)) for e, s in shard_drop_at)
        self.shard_raise_at = frozenset(int(e) for e in shard_raise_at)
        self.slow_shard_at = frozenset(int(e) for e in slow_shard_at)
        self.pump_death_at = frozenset(int(e) for e in pump_death_at)
        self.compact_fault_at = frozenset(int(e) for e in compact_fault_at)
        self.ckpt_truncate_at = frozenset(int(e) for e in ckpt_truncate_at)
        self._lock = threading.Lock()
        self._events = {s: 0 for s in SITES}

    # -------------------------------------------------------------- schedule
    def _next_event(self, site: str) -> int:
        with self._lock:
            n = self._events[site]
            self._events[site] = n + 1
        return n

    def events(self, site: str) -> int:
        """How many events this site has seen (for assertions/reports)."""
        if site not in _SITE_CODE:
            raise ValueError(f"unknown fault site {site!r}; sites: {SITES}")
        with self._lock:
            return self._events[site]

    def _roll(self, site: str, n: int, extra: int = 0) -> float:
        """The deterministic uniform draw for event ``n`` at ``site``."""
        rng = np.random.default_rng(
            (self.seed, _SITE_CODE[site], int(n), int(extra)))
        return float(rng.random())

    def _hit(self, site: str, n: int, rate: float, extra: int = 0) -> bool:
        return rate > 0.0 and self._roll(site, n, extra) < rate

    # ---------------------------------------------------------------- parse
    @classmethod
    def from_spec(cls, spec: str) -> "FaultPlan":
        """Parse the CLI form: ``"seed=7,shard_drop=0.1,slow_ms=5"``.

        Keys are the scalar constructor knobs (rates, ``seed``,
        ``slow_ms``, ``truncate_frac``); the ``*_at`` schedules are
        API-only.  Unknown keys raise ``ValueError``.
        """
        kwargs = {}
        for item in filter(None, (p.strip() for p in spec.split(","))):
            key, sep, value = item.partition("=")
            if not sep:
                raise ValueError(f"bad --faults item {item!r}; expected "
                                 f"key=value")
            key = key.strip()
            if key == "seed":
                kwargs[key] = int(value)
            elif key in _RATES + ("slow_ms", "truncate_frac"):
                kwargs[key] = float(value)
            else:
                raise ValueError(
                    f"unknown fault knob {key!r}; known: seed, slow_ms, "
                    f"truncate_frac, {', '.join(_RATES)}")
        return cls(**kwargs)

    def describe(self) -> str:
        on = [f"{name}={getattr(self, name):g}" for name in _RATES
              if getattr(self, name) > 0.0 or getattr(self, name + "_at")]
        return (f"FaultPlan(seed={self.seed}"
                + (", " + ", ".join(on) if on else "") + ")")

    __repr__ = describe


# --------------------------------------------------------------------------
# installation
# --------------------------------------------------------------------------

_ACTIVE: Optional[FaultPlan] = None


def install(plan: Optional[FaultPlan]) -> None:
    """Install ``plan`` process-wide (None clears)."""
    global _ACTIVE
    _ACTIVE = plan


def clear() -> None:
    install(None)


def active_plan() -> Optional[FaultPlan]:
    return _ACTIVE


@contextmanager
def injected(plan: FaultPlan):
    """Scope a plan: install on entry, restore the previous one on exit."""
    prev = _ACTIVE
    install(plan)
    try:
        yield plan
    finally:
        install(prev)


# --------------------------------------------------------------------------
# site hooks (called by production code; no-ops without a plan)
# --------------------------------------------------------------------------

def shard_events(n_shards: int) -> Optional[np.ndarray]:
    """Sharded-search hook: one call = one search dispatch.

    May raise :class:`~repro.serve.errors.ShardFault` (``shard_raise``),
    sleep (``slow_shard``), and returns a ``[n_shards]`` bool keep-mask
    when any shard is dropped this call — or None (no degradation).
    """
    plan = _ACTIVE
    if plan is None:
        return None
    n = plan._next_event("shard_raise")
    if n in plan.shard_raise_at or plan._hit("shard_raise", n,
                                             plan.shard_raise):
        raise ShardFault(
            f"injected: sharded search raised before dispatch "
            f"(event {n}, seed {plan.seed}) — transient, retry")
    m = plan._next_event("slow_shard")
    if m in plan.slow_shard_at or plan._hit("slow_shard", m,
                                            plan.slow_shard):
        time.sleep(plan.slow_ms / 1e3)
    e = plan._next_event("shard_drop")
    drop = [s for s in range(int(n_shards))
            if (e, s) in plan.shard_drop_at
            or plan._hit("shard_drop", e, plan.shard_drop, extra=s + 1)]
    if not drop:
        return None
    keep = np.ones(int(n_shards), bool)
    keep[drop] = False
    return keep


def pump_tick() -> None:
    """AsyncEngine pump hook, called once per served batch OUTSIDE the
    per-batch error handler — an injected :class:`PumpFault` genuinely
    kills the loop, which is exactly what the supervisor must survive."""
    plan = _ACTIVE
    if plan is None:
        return
    n = plan._next_event("pump_death")
    if n in plan.pump_death_at or plan._hit("pump_death", n,
                                            plan.pump_death):
        raise PumpFault(f"injected: pump thread crashed "
                        f"(event {n}, seed {plan.seed})")


def compaction_attempt() -> None:
    """``mutate.delta.compact`` hook, called before the rebuild — an
    injected failure raises before any new state exists, so the caller's
    serving state is untouched by construction."""
    plan = _ACTIVE
    if plan is None:
        return
    n = plan._next_event("compact_fault")
    if n in plan.compact_fault_at or plan._hit("compact_fault", n,
                                               plan.compact_fault):
        raise CompactionError(
            f"injected: compaction rebuild failed (event {n}, "
            f"seed {plan.seed}); serving state untouched")


def checkpoint_keep_bytes(nbytes: int) -> Optional[int]:
    """``serve.checkpoint.save`` hook: how many bytes of the written file
    to KEEP (truncation injection), or None for an intact save."""
    plan = _ACTIVE
    if plan is None:
        return None
    n = plan._next_event("ckpt_truncate")
    if n in plan.ckpt_truncate_at or plan._hit("ckpt_truncate", n,
                                               plan.ckpt_truncate):
        return max(1, int(int(nbytes) * plan.truncate_frac))
    return None


# --------------------------------------------------------------------------
# degraded-call note (observability for direct sharded_search callers)
# --------------------------------------------------------------------------

_TLS = threading.local()


def note_degraded(coverage: float, failed_shards: Tuple[int, ...]) -> None:
    """Record this thread's most recent degraded search (coverage +
    failed shard indices).  The Engine path computes coverage itself;
    this note is how direct ``sharded_search`` callers observe what the
    installed plan did to their call."""
    _TLS.last = (float(coverage), tuple(int(s) for s in failed_shards))


def last_degraded() -> Optional[Tuple[float, Tuple[int, ...]]]:
    """``(coverage, failed_shards)`` of this thread's last degraded
    search, or None if none was noted."""
    return getattr(_TLS, "last", None)


def clear_degraded() -> None:
    _TLS.last = None
