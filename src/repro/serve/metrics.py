"""Serving-tier metrics: latency percentiles + request counters.

The paper's batch loop reports throughput (QPS) and recall; an open
request stream is judged on *tail latency* — the p95/p99 a user actually
experiences, including queueing delay, not just device time.  This module
is the accounting layer the async serving tier threads every request
through:

  * :class:`LatencyHistogram` — an O(1)-memory log-bucketed histogram
    (HdrHistogram-style): geometric buckets give a bounded ~5% relative
    error on any percentile regardless of sample count, so a serving
    process can record millions of requests without storing them.
  * :class:`ServeMetrics` — thread-safe counters (submitted / served /
    timed_out / rejected / batches / padded) plus one latency histogram
    per tenant and one overall, with a ``snapshot()`` dict the CI gates
    and launchers print.

Latencies are recorded in SECONDS (``time.perf_counter`` deltas measured
from ``submit()`` to ticket resolution — queue wait + batching + device
time); snapshots report milliseconds, the unit SLOs are written in.
"""

from __future__ import annotations

import math
import threading
from collections import Counter
from typing import Dict, Optional

#: counter names ServeMetrics tracks; anything else is rejected so typos
#: in instrumentation fail loudly instead of minting a new silent counter.
#: degraded / retried / failed / compaction* are the fault-tolerance
#: layer's accounting: degraded = served with coverage < 1 (a shard was
#: down), retried = micro-batch retry attempts, failed = tickets resolved
#: to a typed error other than deadline/admission.
COUNTERS = ("submitted", "served", "timed_out", "rejected", "batches",
            "padded", "degraded", "retried", "failed", "compactions",
            "compaction_failed")

#: aggregate key for the cross-tenant histogram / counters.
ALL_TENANTS = "__all__"


class LatencyHistogram:
    """Log-bucketed latency recorder with bounded-error percentiles.

    Buckets are geometric between ``lo_s`` and ``hi_s`` with
    ``bins_per_decade`` buckets per decade, so every percentile estimate
    is within half a bucket width (~``10**(1/(2*bins_per_decade)) - 1``
    relative error, ~2.4% at the default 48/decade) of the true sample
    percentile.  Samples outside the range clamp to the end buckets; the
    exact min/max/sum are tracked alongside, so ``mean`` and the extremes
    are exact.
    """

    def __init__(self, lo_s: float = 1e-6, hi_s: float = 120.0,
                 bins_per_decade: int = 48):
        self.lo_s = float(lo_s)
        self.hi_s = float(hi_s)
        self._scale = bins_per_decade / math.log(10.0)
        n = int(math.ceil(math.log(hi_s / lo_s) * self._scale)) + 1
        self._counts = [0] * n
        self.count = 0
        self.sum_s = 0.0
        self.min_s = math.inf
        self.max_s = 0.0

    def _bucket(self, seconds: float) -> int:
        if seconds <= self.lo_s:
            return 0
        i = int(math.log(seconds / self.lo_s) * self._scale)
        return min(i, len(self._counts) - 1)

    def record(self, seconds: float) -> None:
        self._counts[self._bucket(seconds)] += 1
        self.count += 1
        self.sum_s += seconds
        self.min_s = min(self.min_s, seconds)
        self.max_s = max(self.max_s, seconds)

    def percentile(self, p: float) -> float:
        """Estimated p-th percentile in seconds (nan when empty)."""
        if self.count == 0:
            return math.nan
        rank = max(1, math.ceil(self.count * p / 100.0))
        seen = 0
        for i, c in enumerate(self._counts):
            seen += c
            if seen >= rank:
                # geometric midpoint of the bucket, clamped to the exact
                # extremes so p0/p100 can never leave the observed range
                lo = self.lo_s * math.exp(i / self._scale)
                hi = self.lo_s * math.exp((i + 1) / self._scale)
                return min(max(math.sqrt(lo * hi), self.min_s), self.max_s)
        return self.max_s                     # pragma: no cover - defensive

    @property
    def mean_s(self) -> float:
        return self.sum_s / self.count if self.count else math.nan

    def snapshot_ms(self) -> Dict[str, float]:
        """{count, mean, p50, p95, p99, max} with latencies in ms."""
        ms = 1e3
        return {
            "count": self.count,
            "mean": self.mean_s * ms,
            "p50": self.percentile(50) * ms,
            "p95": self.percentile(95) * ms,
            "p99": self.percentile(99) * ms,
            "max": (self.max_s * ms) if self.count else math.nan,
        }


class ServeMetrics:
    """Thread-safe request counters + per-tenant latency histograms.

    The pump thread and any number of client threads record concurrently;
    a single lock guards every update (the critical sections are a few
    integer adds — contention is negligible next to a device call).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {ALL_TENANTS: Counter()}
        self._hists: Dict[str, LatencyHistogram] = {
            ALL_TENANTS: LatencyHistogram()}
        # coverage fraction per served request (1.0 = every shard
        # answered); the histogram machinery is unit-agnostic, the
        # [1e-3, 1] range spans "one shard of a thousand survived" to
        # "full coverage" with the usual ~2.4% bucket error.
        self._coverage: Dict[str, LatencyHistogram] = {
            ALL_TENANTS: LatencyHistogram(lo_s=1e-3, hi_s=1.0)}

    def _tenant_counter(self, tenant: Optional[str]) -> Counter:
        if tenant is None:
            tenant = ALL_TENANTS
        if tenant not in self._counters:
            self._counters[tenant] = Counter()
        return self._counters[tenant]

    def count(self, name: str, n: int = 1,
              tenant: Optional[str] = None) -> None:
        if name not in COUNTERS:
            raise ValueError(f"unknown serve counter {name!r}; "
                             f"tracked: {COUNTERS}")
        with self._lock:
            self._counters[ALL_TENANTS][name] += n
            if tenant is not None:
                self._tenant_counter(tenant)[name] += n

    def observe(self, seconds: float, tenant: Optional[str] = None) -> None:
        """Record one request's submit-to-answer latency."""
        with self._lock:
            self._hists[ALL_TENANTS].record(seconds)
            if tenant is not None:
                if tenant not in self._hists:
                    self._hists[tenant] = LatencyHistogram()
                self._hists[tenant].record(seconds)

    def observe_coverage(self, coverage: float,
                         tenant: Optional[str] = None) -> None:
        """Record one served request's shard coverage (1.0 = full).

        Recorded for EVERY served request, not just degraded ones, so the
        per-tenant percentiles mean "the coverage the p-th worst request
        actually got" — the number an availability SLO is written
        against."""
        with self._lock:
            self._coverage[ALL_TENANTS].record(coverage)
            if tenant is not None:
                if tenant not in self._coverage:
                    self._coverage[tenant] = LatencyHistogram(
                        lo_s=1e-3, hi_s=1.0)
                self._coverage[tenant].record(coverage)

    # ------------------------------------------------------------- reading
    def counter(self, name: str, tenant: Optional[str] = None) -> int:
        with self._lock:
            return self._counters.get(tenant or ALL_TENANTS,
                                      Counter())[name]

    def percentile(self, p: float, tenant: Optional[str] = None) -> float:
        """p-th latency percentile in SECONDS (nan when empty)."""
        with self._lock:
            hist = self._hists.get(tenant or ALL_TENANTS)
            return hist.percentile(p) if hist else math.nan

    def coverage_percentile(self, p: float,
                            tenant: Optional[str] = None) -> float:
        """p-th percentile of served coverage (nan when empty).  Low
        percentiles are the interesting tail: p5 is the coverage the 5%
        worst-covered requests got."""
        with self._lock:
            hist = self._coverage.get(tenant or ALL_TENANTS)
            return hist.percentile(p) if hist else math.nan

    @staticmethod
    def _coverage_snapshot(hist: LatencyHistogram) -> Dict[str, float]:
        return {
            "count": hist.count,
            "mean": hist.mean_s,
            "p5": hist.percentile(5),
            "p50": hist.percentile(50),
            "min": hist.min_s if hist.count else math.nan,
        }

    def snapshot(self) -> dict:
        """One JSON-able dict: overall counters + latency (ms) +
        coverage percentiles + the same per tenant — what launchers
        print and ``bench_serving`` writes into ``BENCH_serving.json``."""
        with self._lock:
            out = {
                "counters": dict(self._counters[ALL_TENANTS]),
                "latency_ms": self._hists[ALL_TENANTS].snapshot_ms(),
                "coverage": self._coverage_snapshot(
                    self._coverage[ALL_TENANTS]),
                "tenants": {},
            }
            for tenant, hist in self._hists.items():
                if tenant == ALL_TENANTS:
                    continue
                entry = {
                    "counters": dict(self._counters.get(tenant, Counter())),
                    "latency_ms": hist.snapshot_ms(),
                }
                if tenant in self._coverage:
                    entry["coverage"] = self._coverage_snapshot(
                        self._coverage[tenant])
                out["tenants"][tenant] = entry
            return out
