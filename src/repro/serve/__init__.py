"""Serving tier over the functional index core.

Layers, composing upward:

  * :mod:`repro.serve.engine` — the synchronous micro-batching
    :class:`Engine` (one fixed padded trace per resident IndexState), the
    :class:`Ticket` request future, and background :class:`Compaction`
    handles.
  * :mod:`repro.serve.async_engine` — the SLO-aware background pump
    (:class:`AsyncEngine`): timeout-based flush, per-request deadlines,
    admission control, multi-tenant routing, latency percentiles, plus
    the fault-tolerance surface (retries, degraded coverage, pump
    supervisor).
  * :mod:`repro.serve.checkpoint` — the one checkpoint surface
    (single-state ``.npz`` + multi-tenant archives, explicit version
    negotiation, corrupt-file hardening).
  * :mod:`repro.serve.retry` — :class:`RetryPolicy`: exponential backoff
    with deterministic jitter for transient faults.
  * :mod:`repro.serve.faults` — deterministic fault injection
    (:class:`FaultPlan`) for chaos tests and the availability benchmark.
"""

from repro.serve import faults
from repro.serve.async_engine import DEFAULT_TENANT, AsyncEngine
from repro.serve.checkpoint import (ARCHIVE_VERSION, CHECKPOINT_VERSION,
                                    CheckpointError, load_state, save_state)
from repro.serve.engine import Compaction, Engine, Ticket
from repro.serve.errors import (AdmissionError, CompactionError,
                                DeadlineExceeded, EngineClosed,
                                EngineDegraded, RetriesExhausted,
                                ServeError, ShardFault, TransientFault)
from repro.serve.faults import FaultPlan, PumpFault
from repro.serve.metrics import LatencyHistogram, ServeMetrics
from repro.serve.retry import RetryPolicy

__all__ = [
    "Engine", "Ticket", "Compaction", "AsyncEngine", "DEFAULT_TENANT",
    "ServeMetrics", "LatencyHistogram",
    "ServeError", "AdmissionError", "DeadlineExceeded", "EngineClosed",
    "EngineDegraded", "TransientFault", "ShardFault", "RetriesExhausted",
    "CompactionError", "PumpFault", "FaultPlan", "RetryPolicy", "faults",
    "CheckpointError", "CHECKPOINT_VERSION", "ARCHIVE_VERSION",
    "save_state", "load_state",
]
