"""Serving tier over the functional index core.

Three layers, composing upward:

  * :mod:`repro.serve.engine` — the synchronous micro-batching
    :class:`Engine` (one fixed padded trace per resident IndexState) and
    the :class:`Ticket` request future.
  * :mod:`repro.serve.async_engine` — the SLO-aware background pump
    (:class:`AsyncEngine`): timeout-based flush, per-request deadlines,
    admission control, multi-tenant routing, latency percentiles.
  * :mod:`repro.serve.checkpoint` — the one checkpoint surface
    (single-state ``.npz`` + multi-tenant archives, explicit version
    negotiation).
"""

from repro.serve.async_engine import DEFAULT_TENANT, AsyncEngine
from repro.serve.checkpoint import (ARCHIVE_VERSION, CHECKPOINT_VERSION,
                                    CheckpointError, load_state, save_state)
from repro.serve.engine import Engine, Ticket
from repro.serve.errors import (AdmissionError, DeadlineExceeded,
                                EngineClosed, ServeError)
from repro.serve.metrics import LatencyHistogram, ServeMetrics

__all__ = [
    "Engine", "Ticket", "AsyncEngine", "DEFAULT_TENANT",
    "ServeMetrics", "LatencyHistogram",
    "ServeError", "AdmissionError", "DeadlineExceeded", "EngineClosed",
    "CheckpointError", "CHECKPOINT_VERSION", "ARCHIVE_VERSION",
    "save_state", "load_state",
]
