"""Serving layer: micro-batching Engine over the functional index core."""

from repro.serve.engine import (CHECKPOINT_VERSION, CheckpointError, Engine,
                                load_state, save_state)

__all__ = ["Engine", "CheckpointError", "CHECKPOINT_VERSION",
           "save_state", "load_state"]
