"""Typed errors for the serving tier.

Every failure mode a client can hit has its own type, so callers (and the
load-generator gates in ``benchmarks/bench_serving.py``) discriminate by
``except`` clause instead of string-matching messages:

  * :class:`AdmissionError` — the bounded request queue is full; the
    request was REJECTED at ``submit()`` and never queued.  Load shedding
    is explicit: under overload the serving tier answers "no" immediately
    rather than queueing unboundedly and missing every deadline.
  * :class:`DeadlineExceeded` — the request WAS admitted but its
    per-request deadline expired before (or while) its micro-batch ran;
    ``ticket.result()`` raises this instead of returning stale answers.
    Subclasses :class:`TimeoutError` so generic timeout handling works.
  * :class:`EngineClosed` — ``submit()`` after the pump was shut down.
  * :class:`EngineDegraded` — the pump thread died; its supervisor failed
    every outstanding ticket with this (so ``ticket.result()`` can never
    hang on a dead pump) and ``submit()`` refuses new work.
  * :class:`TransientFault` / :class:`ShardFault` — retryable failures;
    the pump's :class:`~repro.serve.retry.RetryPolicy` retries these with
    backoff before they surface.
  * :class:`RetriesExhausted` — a transient fault outlived the retry
    budget (attempts, or every live deadline); wraps the last cause.
  * :class:`CompactionError` — a compaction rebuild failed (including an
    injected fault); the serving state is guaranteed untouched.

:class:`~repro.serve.checkpoint.CheckpointError` lives with the
checkpoint code; it is re-exported from :mod:`repro.serve` alongside
these.
"""

from __future__ import annotations


class ServeError(RuntimeError):
    """Base class for serving-tier request failures."""


class AdmissionError(ServeError):
    """Request rejected at submit(): the bounded queue is at capacity."""


class DeadlineExceeded(ServeError, TimeoutError):
    """Request admitted but its deadline expired before it was answered."""


class EngineClosed(ServeError):
    """Request submitted to a pump that has been shut down."""


class EngineDegraded(ServeError):
    """The pump thread died: outstanding tickets were failed with this
    and ``submit()`` refuses new requests — build a fresh AsyncEngine
    (the resident Engines and their states are still intact)."""


class TransientFault(ServeError):
    """A retryable serving failure (the fault may pass on a retry).

    The async pump retries these under its
    :class:`~repro.serve.retry.RetryPolicy`; only an exhausted budget
    surfaces, as :class:`RetriesExhausted`."""


class ShardFault(TransientFault):
    """A sharded search attempt failed outright (e.g. an injected shard
    crash before dispatch) — transient, distinct from graceful
    degradation where the merge proceeds over the surviving shards."""


class RetriesExhausted(ServeError):
    """A transient fault persisted past the retry budget (max attempts,
    or no live request deadline could absorb another backoff)."""


class CompactionError(ServeError):
    """A compaction rebuild failed; the pre-compaction serving state is
    untouched (the rebuild is pure — nothing swaps until it succeeds)."""
