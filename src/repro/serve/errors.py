"""Typed errors for the serving tier.

Every failure mode a client can hit has its own type, so callers (and the
load-generator gates in ``benchmarks/bench_serving.py``) discriminate by
``except`` clause instead of string-matching messages:

  * :class:`AdmissionError` — the bounded request queue is full; the
    request was REJECTED at ``submit()`` and never queued.  Load shedding
    is explicit: under overload the serving tier answers "no" immediately
    rather than queueing unboundedly and missing every deadline.
  * :class:`DeadlineExceeded` — the request WAS admitted but its
    per-request deadline expired before (or while) its micro-batch ran;
    ``ticket.result()`` raises this instead of returning stale answers.
    Subclasses :class:`TimeoutError` so generic timeout handling works.
  * :class:`EngineClosed` — ``submit()`` after the pump was shut down.

:class:`~repro.serve.checkpoint.CheckpointError` lives with the
checkpoint code; it is re-exported from :mod:`repro.serve` alongside
these.
"""

from __future__ import annotations


class ServeError(RuntimeError):
    """Base class for serving-tier request failures."""


class AdmissionError(ServeError):
    """Request rejected at submit(): the bounded queue is at capacity."""


class DeadlineExceeded(ServeError, TimeoutError):
    """Request admitted but its deadline expired before it was answered."""


class EngineClosed(ServeError):
    """Request submitted to a pump that has been shut down."""
