"""The one checkpoint surface for the serving tier.

Everything that persists an :class:`~repro.ann.functional.IndexState`
goes through this module — ``Engine.save``/``Engine.load``,
``AsyncEngine.save``/``AsyncEngine.load`` and the standalone helpers are
all thin wrappers over ONE documented entry pair:

    checkpoint.save(path, target, extra=...)   # target: IndexState | mapping
    checkpoint.load(path) -> CheckpointContents  # tenant -> (state, extra)

Two on-disk formats, auto-detected on load:

  * **single state** — one ``.npz``: the IndexState's array leaves plus a
    JSON metadata record (format version, algo, metric, static dict,
    engine extras).  Written when ``target`` is an ``IndexState``.
  * **multi-tenant archive** — one zip with a ``manifest.json`` and one
    single-state member per resident tenant, so a multi-tenant serving
    process checkpoints/restores ALL of its indexes atomically in one
    file.  Written when ``target`` is a mapping ``tenant -> IndexState``
    (or ``tenant -> (IndexState, extra)``).

**Version negotiation** is explicit: every rejection says which version
the file has, which this build reads, and — for known historical versions
— WHY the file is unusable (v1 pre-dates the cached ``xsq`` norms table,
so euclidean E2LSH/RPForest states would load and then fail at query
time) versus the generic stale/newer messages.  All failure modes raise
:class:`CheckpointError`.

**Mesh portability**: sharded states (``Sharded*``) are saved exactly like
any other state — their arrays gather to host and their ``static`` dict
carries the mesh *recipe* (``shard_axes`` + ``mesh_shape``) as plain JSON.
No device topology is baked into the file, so v4 checkpoints restore on
any host: a compatible recipe re-lays the arrays out over the local mesh
on first search, an oversized one is either rejected by ``search`` with
the reshard instruction or adapted automatically by
``repro.dist.shard_state.ensure_servable`` (the ``Engine`` restore path).
"""

from __future__ import annotations

import io
import json
import zipfile
import zlib
from pathlib import Path
from typing import Any, Dict, Mapping, Optional, Tuple

import numpy as np

import jax.numpy as jnp

from repro.ann.functional import IndexState

#: single-state format version; bump when the on-disk layout changes.
#: v2: euclidean E2LSH/RPForest states grew a cached ``xsq`` array (the
#: fused-rerank norms table) — v1 checkpoints of those indexes would load
#: but fail at query time, so v1 is rejected with that explanation.
#: v3: compressed-domain (``quantize=``) states carry ``codes``/
#: ``codebooks`` leaves and the ``quant`` static descriptor; pre-quant v2
#: metadata has no codec contract, so v2 is rejected with that explanation.
#: v4: streaming-mutation (Mutable*) states nest a whole inner IndexState
#: under the ``main`` leaf plus delta-buffer/tombstone arrays; the v3
#: array layout is flat-only, so v3 is rejected with that explanation.
CHECKPOINT_VERSION = 4

#: multi-tenant archive format version (manifest + member layout).
ARCHIVE_VERSION = 1

_META_KEY = "__repro_meta__"
_MANIFEST = "manifest.json"

#: why a known old single-state version is rejected — each gets its own
#: message so operators can tell "rebuild required" from "wrong build".
_VERSION_NOTES = {
    1: ("v1 pre-dates the cached xsq norms table: euclidean E2LSH/RPForest "
        "states would load but fail at query time; rebuild the index "
        "(Engine.build) and re-save"),
    2: ("v2 pre-dates compressed-domain search: quantized (quantize=) "
        "states carry codes/codebooks and a quant descriptor the v2 "
        "metadata cannot express, so a PQ/int8 index restored from it "
        "would search without its codec; rebuild the index (Engine.build) "
        "and re-save"),
    3: ("v3 pre-dates streaming mutation: mutable (Mutable*) states nest "
        "an inner IndexState plus delta-buffer and tombstone leaves the "
        "flat v3 layout cannot express — pending inserts would be lost "
        "and deleted rows resurrected; rebuild the index (Engine.build) "
        "and re-save"),
}


class CheckpointError(RuntimeError):
    """Raised for unreadable, stale, or mismatched checkpoints."""


class CheckpointContents(Dict[str, Tuple[IndexState, dict]]):
    """What :func:`load` returns: ``tenant -> (state, extra)``.

    A single-state checkpoint loads as one ``"default"`` entry; ``.only``
    unwraps it (and raises on a multi-tenant archive, so code written for
    one index cannot silently pick an arbitrary tenant).
    """

    @property
    def only(self) -> Tuple[IndexState, dict]:
        if len(self) != 1:
            raise CheckpointError(
                f"checkpoint holds {len(self)} tenant states "
                f"({sorted(self)}); load it with checkpoint.load / "
                f"AsyncEngine.load, not the single-state API")
        return next(iter(self.values()))


# --------------------------------------------------------------------------
# single-state format: IndexState <-> npz bytes
# --------------------------------------------------------------------------

def _flatten_arrays(arrays: Dict[str, Any], prefix: str = ""):
    """name -> array | tuple-of-arrays | IndexState  ==>  flat {key: np}.

    A nested :class:`IndexState` value (the mutable indexes' ``main``
    leaf, v4) recurses with a ``name::`` key prefix; its layout entry
    records everything needed to rebuild it (algo/metric/static +
    sub-layout), so arbitrary nesting round-trips.
    """
    flat: Dict[str, np.ndarray] = {}
    layout: Dict[str, Any] = {}
    for name in sorted(arrays):
        value = arrays[name]
        if isinstance(value, IndexState):
            sub_flat, sub_layout = _flatten_arrays(
                value.arrays, prefix=f"{prefix}{name}::")
            flat.update(sub_flat)
            layout[name] = {"state": {
                "algo": value.algo, "metric": value.metric,
                "static": {k: _jsonable(v) for k, v in value.static.items()},
                "layout": sub_layout,
            }}
        elif isinstance(value, (tuple, list)):
            layout[name] = len(value)
            for i, leaf in enumerate(value):
                flat[f"{prefix}{name}:{i}"] = np.asarray(leaf)
        else:
            layout[name] = None
            flat[f"{prefix}{name}"] = np.asarray(value)
    return flat, layout


def _unflatten_arrays(npz, layout: Dict[str, Any], prefix: str = ""):
    arrays: Dict[str, Any] = {}
    for name, entry in layout.items():
        if isinstance(entry, dict):
            sub = entry["state"]
            arrays[name] = IndexState(
                sub["algo"], sub["metric"],
                _unflatten_arrays(npz, sub["layout"],
                                  prefix=f"{prefix}{name}::"),
                {k: _unjsonable(v) for k, v in sub["static"].items()})
        elif entry is None:
            arrays[name] = jnp.asarray(npz[f"{prefix}{name}"])
        else:
            arrays[name] = tuple(
                jnp.asarray(npz[f"{prefix}{name}:{i}"])
                for i in range(entry))
    return arrays


def _jsonable(v):
    if isinstance(v, tuple):
        return {"__tuple__": [_jsonable(x) for x in v]}
    return v


def _unjsonable(v):
    if isinstance(v, dict) and "__tuple__" in v:
        return tuple(_unjsonable(x) for x in v["__tuple__"])
    if isinstance(v, list):
        return tuple(_unjsonable(x) for x in v)
    return v


def _state_npz_bytes(state: IndexState, extra: Optional[dict]) -> bytes:
    flat, layout = _flatten_arrays(state.arrays)
    meta = {
        "version": CHECKPOINT_VERSION,
        "algo": state.algo,
        "metric": state.metric,
        "static": {k: _jsonable(v) for k, v in state.static.items()},
        "layout": layout,
        "extra": extra or {},
    }
    blob = np.frombuffer(json.dumps(meta).encode(), dtype=np.uint8)
    buf = io.BytesIO()
    np.savez(buf, **{_META_KEY: blob}, **flat)
    return buf.getvalue()


def _check_version(what: str, version) -> None:
    if version == CHECKPOINT_VERSION:
        return
    if isinstance(version, int) and version > CHECKPOINT_VERSION:
        hint = ("written by a NEWER build — upgrade this install to read "
                "it (or re-save from the old one)")
    else:
        hint = _VERSION_NOTES.get(
            version, "rebuild the index (Engine.build) and re-save")
    raise CheckpointError(
        f"{what} has format version {version!r}, this build reads "
        f"version {CHECKPOINT_VERSION}; {hint}")


def _state_from_npz(file_like, what: str,
                    nbytes: Optional[int] = None) -> Tuple[IndexState, dict]:
    """Parse one single-state npz; every way a truncated or bit-flipped
    file can fail (bad zip directory, short member, zlib CRC, mangled
    JSON, missing array key) surfaces as :class:`CheckpointError` naming
    the file and its byte size — never a raw decoder traceback."""
    size = "" if nbytes is None else f" ({nbytes} bytes on disk)"
    try:
        with np.load(file_like) as z:
            if _META_KEY not in z:
                raise CheckpointError(
                    f"{what} is not an Engine checkpoint (missing metadata "
                    f"record; was it written by the old pickle path?)")
            meta = json.loads(bytes(z[_META_KEY].tobytes()).decode())
            _check_version(what, meta.get("version"))
            arrays = _unflatten_arrays(z, meta["layout"])
    except (zipfile.BadZipFile, zlib.error, ValueError, OSError,
            EOFError, KeyError) as e:
        raise CheckpointError(
            f"unreadable or corrupt checkpoint {what}{size}: "
            f"{type(e).__name__}: {e} — the file is likely truncated or "
            f"bit-flipped; restore from a good copy (save() writes "
            f"atomically, so a crashed writer cannot produce this)") from e
    static = {k: _unjsonable(v) for k, v in meta["static"].items()}
    state = IndexState(meta["algo"], meta["metric"], arrays, static)
    return state, meta.get("extra", {})


# --------------------------------------------------------------------------
# the entry pair
# --------------------------------------------------------------------------

def save(path, target, *, extra: Optional[dict] = None) -> Path:
    """Serialise ``target`` to ``path`` (atomically, via a tmp rename).

    ``target`` is either one :class:`IndexState` (single-state ``.npz``;
    ``extra`` rides in its metadata record) or a mapping ``tenant ->
    IndexState`` / ``tenant -> (IndexState, extra_dict)`` (multi-tenant
    archive; ``extra=`` is then disallowed — extras are per tenant).
    """
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(path.suffix + ".tmp")
    if isinstance(target, IndexState):
        tmp.write_bytes(_state_npz_bytes(target, extra))
    elif isinstance(target, Mapping):
        if extra is not None:
            raise ValueError("extra= is per-tenant for archives; pass "
                             "tenant -> (state, extra) pairs instead")
        members = {}
        for i, (tenant, value) in enumerate(sorted(target.items())):
            state, tenant_extra = (value if isinstance(value, tuple)
                                   else (value, None))
            members[str(tenant)] = (f"states/{i}.npz",
                                    _state_npz_bytes(state, tenant_extra))
        manifest = {
            "archive_version": ARCHIVE_VERSION,
            "checkpoint_version": CHECKPOINT_VERSION,
            "tenants": {t: m for t, (m, _) in members.items()},
        }
        with zipfile.ZipFile(tmp, "w", zipfile.ZIP_STORED) as zf:
            zf.writestr(_MANIFEST, json.dumps(manifest, indent=2))
            for member, blob in members.values():
                zf.writestr(member, blob)
    else:
        raise TypeError(f"cannot checkpoint {type(target).__name__}; "
                        f"pass an IndexState or a tenant mapping")
    # fault-injection point: a FaultPlan with ckpt_truncate scheduled
    # chops the TMP file before the atomic rename, simulating a torn
    # write that somehow got renamed (e.g. a dying disk acking early) —
    # load() must answer with CheckpointError, never a decoder traceback
    from repro.serve import faults as _faults
    keep = _faults.checkpoint_keep_bytes(tmp.stat().st_size)
    if keep is not None:
        with open(tmp, "r+b") as f:
            f.truncate(keep)
    tmp.replace(path)
    return path


def load(path) -> CheckpointContents:
    """Deserialise ``path`` -> :class:`CheckpointContents` (either format).

    Raises :class:`CheckpointError` on missing files, non-checkpoint
    files, or any format-version mismatch (see the module docstring for
    the negotiation rules).
    """
    path = Path(path)
    if not path.exists():
        raise CheckpointError(f"no checkpoint at {path}")
    nbytes = path.stat().st_size
    try:
        with zipfile.ZipFile(path) as zf:
            names = set(zf.namelist())
            if _MANIFEST in names:
                return _load_archive(path, zf)
            if f"{_META_KEY}.npy" not in names:
                raise CheckpointError(
                    f"{path} is not an Engine checkpoint (missing metadata "
                    f"record; was it written by the old pickle path?)")
    except (zipfile.BadZipFile, EOFError, OSError) as e:
        raise CheckpointError(
            f"unreadable or corrupt checkpoint {path} ({nbytes} bytes on "
            f"disk): {type(e).__name__}: {e} — the file is likely "
            f"truncated or bit-flipped; restore from a good copy") from e
    state, extra = _state_from_npz(path, str(path), nbytes=nbytes)
    return CheckpointContents(default=(state, extra))


def _load_archive(path: Path, zf: zipfile.ZipFile) -> CheckpointContents:
    try:
        manifest = json.loads(zf.read(_MANIFEST).decode())
    except ValueError as e:
        raise CheckpointError(
            f"unreadable archive manifest in {path}: {e}") from e
    version = manifest.get("archive_version")
    if version != ARCHIVE_VERSION:
        raise CheckpointError(
            f"archive {path} has archive version {version!r}, this build "
            f"reads archive version {ARCHIVE_VERSION}; re-save the archive "
            f"(AsyncEngine.save) with a matching build")
    out = CheckpointContents()
    for tenant, member in manifest.get("tenants", {}).items():
        what = f"{path}[{tenant}]"
        try:
            blob = zf.read(member)
        except KeyError as e:
            raise CheckpointError(
                f"archive {path} names member {member!r} for tenant "
                f"{tenant!r} but it is missing") from e
        except (zipfile.BadZipFile, zlib.error, OSError, EOFError) as e:
            raise CheckpointError(
                f"archive member {member!r} for tenant {tenant!r} in "
                f"{path} is unreadable ({type(e).__name__}: {e}) — the "
                f"archive is likely truncated or bit-flipped; restore "
                f"from a good copy") from e
        out[tenant] = _state_from_npz(io.BytesIO(blob), what,
                                      nbytes=len(blob))
    if not out:
        raise CheckpointError(f"archive {path} holds no tenant states")
    return out


# --------------------------------------------------------------------------
# single-state compatibility aliases (pre-ISSUE-6 surface)
# --------------------------------------------------------------------------

def save_state(state: IndexState, path, extra: Optional[dict] = None) -> Path:
    """Serialise one IndexState (+ engine metadata) — ``save(path, state)``."""
    return save(path, state, extra=extra)


def load_state(path) -> Tuple[IndexState, dict]:
    """Deserialise one ``(IndexState, extra)`` — ``load(path).only``."""
    return load(path).only
