"""Batching serve engine over the functional index core.

The experiment loop calls algorithms per query set; a serving system sees an
open-ended stream of variable-size requests.  ``Engine`` turns an immutable
:class:`~repro.ann.functional.IndexState` into that serving surface:

  * **one trace** — the spec's pure ``search`` is jitted once for a fixed
    padded micro-batch shape ``[batch_size, d]``; every request batch is
    padded up to it, so no request size ever retraces;
  * **micro-batching** — ``submit()`` queues single queries, ``flush()``
    answers them in one device call; ``search()`` streams arbitrarily large
    query sets through fixed-size micro-batches (device-resident
    end-to-end on the streaming distance+top-k path);
  * **pytree checkpointing** — ``save()``/``load()`` serialise the
    IndexState's array leaves + static dict to one ``.npz`` with an
    explicit format-version field, replacing the old pickle round-trip of
    live objects (which silently dropped jitted closures and accepted any
    stale file).  A version mismatch raises :class:`CheckpointError`.

Query-time knobs ride along per engine (``query_params=``) and can be
overridden per ``search()`` call or per ``submit()``-ed request; a knob
whose static ``max_*`` cap partner is pinned in ``query_params`` is
automatically demoted to a traced runtime value (the spec's
``traced_knobs``), so per-request quality settings — e.g. IVF's
``n_probes`` under ``max_probes``, HNSW's ``ef`` under ``max_ef`` —
change behaviour *without* recompilation.
"""

from __future__ import annotations

import json
import time
import zipfile
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.ann.functional import IndexState, get_functional

#: bump when the on-disk layout changes; load() rejects anything else.
#: v2: euclidean E2LSH/RPForest states grew a cached ``xsq`` array (the
#: fused-rerank norms table) — v1 checkpoints of those indexes would load
#: but fail at query time, so they are rejected here instead.
CHECKPOINT_VERSION = 2

_META_KEY = "__repro_meta__"


class CheckpointError(RuntimeError):
    """Raised for unreadable, stale, or mismatched checkpoints."""


# --------------------------------------------------------------------------
# IndexState <-> npz
# --------------------------------------------------------------------------

def _flatten_arrays(arrays: Dict[str, Any]):
    """name -> array | tuple-of-arrays  ==>  flat {key: np.ndarray}."""
    flat: Dict[str, np.ndarray] = {}
    layout: Dict[str, Any] = {}
    for name in sorted(arrays):
        value = arrays[name]
        if isinstance(value, (tuple, list)):
            layout[name] = len(value)
            for i, leaf in enumerate(value):
                flat[f"{name}:{i}"] = np.asarray(leaf)
        else:
            layout[name] = None
            flat[name] = np.asarray(value)
    return flat, layout


def _unflatten_arrays(npz, layout: Dict[str, Any]):
    arrays: Dict[str, Any] = {}
    for name, length in layout.items():
        if length is None:
            arrays[name] = jnp.asarray(npz[name])
        else:
            arrays[name] = tuple(
                jnp.asarray(npz[f"{name}:{i}"]) for i in range(length))
    return arrays


def save_state(state: IndexState, path, extra: Optional[dict] = None) -> Path:
    """Serialise an IndexState (+ optional engine metadata) to ``path``."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat, layout = _flatten_arrays(state.arrays)
    meta = {
        "version": CHECKPOINT_VERSION,
        "algo": state.algo,
        "metric": state.metric,
        "static": {k: _jsonable(v) for k, v in state.static.items()},
        "layout": layout,
        "extra": extra or {},
    }
    blob = np.frombuffer(json.dumps(meta).encode(), dtype=np.uint8)
    tmp = path.with_suffix(path.suffix + ".tmp")
    with open(tmp, "wb") as fh:         # file handle: no .npz auto-suffix
        np.savez(fh, **{_META_KEY: blob}, **flat)
    tmp.replace(path)
    return path


def load_state(path) -> Tuple[IndexState, dict]:
    """Deserialise (IndexState, extra-metadata) from ``path``.

    Raises :class:`CheckpointError` on missing files, non-checkpoint files,
    or a format-version mismatch — the failure modes the old pickle path
    surfaced as arbitrary unpickling errors (or not at all).
    """
    path = Path(path)
    if not path.exists():
        raise CheckpointError(f"no checkpoint at {path}")
    try:
        with np.load(path) as z:
            if _META_KEY not in z:
                raise CheckpointError(
                    f"{path} is not an Engine checkpoint (missing metadata "
                    f"record; was it written by the old pickle path?)")
            meta = json.loads(bytes(z[_META_KEY].tobytes()).decode())
            version = meta.get("version")
            if version != CHECKPOINT_VERSION:
                raise CheckpointError(
                    f"checkpoint {path} has format version {version!r}, "
                    f"this build reads version {CHECKPOINT_VERSION}; "
                    f"rebuild the index (Engine.build) and re-save")
            arrays = _unflatten_arrays(z, meta["layout"])
    except (zipfile.BadZipFile, ValueError) as e:
        raise CheckpointError(f"unreadable checkpoint {path}: {e}") from e
    static = {k: _unjsonable(v) for k, v in meta["static"].items()}
    state = IndexState(meta["algo"], meta["metric"], arrays, static)
    return state, meta.get("extra", {})


def _jsonable(v):
    if isinstance(v, tuple):
        return {"__tuple__": [_jsonable(x) for x in v]}
    return v


def _unjsonable(v):
    if isinstance(v, dict) and "__tuple__" in v:
        return tuple(_unjsonable(x) for x in v["__tuple__"])
    if isinstance(v, list):
        return tuple(_unjsonable(x) for x in v)
    return v


# --------------------------------------------------------------------------
# Engine
# --------------------------------------------------------------------------

class Engine:
    """Micro-batching query server over one device-resident IndexState.

    >>> eng = Engine.build("IVF", X, metric="euclidean",
    ...                    build_params={"n_clusters": 64},
    ...                    query_params={"n_probes": 8}, k=10)
    >>> dists, ids = eng.search(Q)          # any nq; fixed-shape batches
    >>> t = eng.submit(q); eng.flush()      # single-query request path
    >>> eng.save("/tmp/ivf.ckpt"); eng2 = Engine.load("/tmp/ivf.ckpt")
    """

    def __init__(self, state: IndexState, *, k: int = 10,
                 batch_size: int = 256,
                 query_params: Optional[Dict[str, Any]] = None,
                 traced_params: Tuple[str, ...] = ()):
        self.spec = get_functional(state.algo)
        self.state = state
        self.k = int(k)
        self.batch_size = int(batch_size)
        self.query_params = self.spec.default_query_params()
        self.query_params.update(query_params or {})
        # ``traced_params`` demotes spec-static knobs to runtime values —
        # e.g. IVF's n_probes under a pinned max_probes cap: the knob then
        # sweeps recall/QPS with zero retraces.  Knobs whose static cap
        # partner is pinned in ``query_params`` are demoted automatically.
        traced = list(traced_params)
        for knob, cap in self.spec.traced_knobs:
            if knob not in traced and self.query_params.get(cap) is not None:
                traced.append(knob)
        # A traced knob whose value is None (= "no limit", e.g. IVF's
        # ``scan``) is pinned to its cap: in traced mode the two are
        # semantically identical, but None and int trace DIFFERENTLY
        # (pytree structure), and serving must keep one trace across
        # later integer updates — e.g. adopting an autotuned value.
        for knob, cap in self.spec.traced_knobs:
            if (knob in traced and self.query_params.get(knob) is None
                    and self.query_params.get(cap) is not None):
                self.query_params[knob] = int(self.query_params[cap])
        self.traced_params = tuple(traced)
        self._search = self.spec.jit_search(traced=self.traced_params)
        self._pending: list = []    # (ticket, np.ndarray [d], key, overrides)
        self._results: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self._next_ticket = 0
        self.stats = {"queries": 0, "batches": 0, "padded": 0,
                      "device_time_s": 0.0}

    # ---------------------------------------------------------- constructors
    @classmethod
    def build(cls, algo: str, X, *, metric: str,
              build_params: Optional[Dict[str, Any]] = None,
              **engine_kwargs) -> "Engine":
        spec = get_functional(algo)
        state = spec.build(X, metric=metric, **(build_params or {}))
        return cls(state, **engine_kwargs)

    @classmethod
    def load(cls, path, **overrides) -> "Engine":
        state, extra = load_state(path)
        kwargs = {"k": extra.get("k", 10),
                  "batch_size": extra.get("batch_size", 256),
                  "query_params": extra.get("query_params") or {},
                  "traced_params": tuple(extra.get("traced_params") or ())}
        kwargs.update(overrides)
        return cls(state, **kwargs)

    def save(self, path) -> Path:
        return save_state(self.state, path, extra={
            "k": self.k, "batch_size": self.batch_size,
            "query_params": {k: v for k, v in self.query_params.items()
                             if _is_plain(v)},
            "traced_params": list(self.traced_params),
        })

    # -------------------------------------------------------------- serving
    def _check_caps(self, params) -> None:
        """Reject knob values above their static cap: the traced search
        would silently clamp them (shapes are fixed at trace time), which
        must not masquerade as the requested quality setting."""
        for knob, cap in self.spec.traced_knobs:
            cap_v, knob_v = params.get(cap), params.get(knob)
            if cap_v is None or knob_v is None:
                continue
            try:
                knob_i = int(np.asarray(knob_v))
            except (TypeError, ValueError):
                continue
            if knob_i > int(cap_v):
                raise ValueError(
                    f"{knob}={knob_i} exceeds the engine's static "
                    f"{cap}={int(cap_v)} (the trace would clamp it); "
                    f"rebuild the Engine with a larger {cap}")

    def _run_padded(self, Qb: np.ndarray, n_live: int, overrides):
        """One fixed-shape device call: Qb is already [batch_size, d]."""
        params = dict(self.query_params)
        params.update(overrides)
        self._check_caps(params)
        t0 = time.perf_counter()
        dists, ids = self._search(self.state, Qb, k=self.k, **params)
        ids = jax.block_until_ready(ids)
        self.stats["device_time_s"] += time.perf_counter() - t0
        self.stats["batches"] += 1
        self.stats["queries"] += n_live
        self.stats["padded"] += Qb.shape[0] - n_live
        return dists, ids

    def _pad_batch(self, Q: np.ndarray) -> np.ndarray:
        pad = self.batch_size - Q.shape[0]
        if pad == 0:
            return Q
        return np.concatenate(
            [Q, np.zeros((pad,) + Q.shape[1:], Q.dtype)], axis=0)

    def search(self, Q, **overrides) -> Tuple[np.ndarray, np.ndarray]:
        """Answer a query set of any size via fixed-shape micro-batches.

        Returns ``(dists [nq, k], ids [nq, k])`` as numpy arrays — the same
        order as every functional ``spec.search``.  Keyword overrides are
        per-call query params (a traced knob changes behaviour with no
        retrace; a static knob retraces once per value).
        """
        Q = np.asarray(Q)
        nq = Q.shape[0]
        if nq == 0:
            return (np.empty((0, self.k), np.float32),
                    np.empty((0, self.k), np.int32))
        ids_out, dists_out = [], []
        for s in range(0, nq, self.batch_size):
            blk = Q[s:s + self.batch_size]
            live = blk.shape[0]
            dists, ids = self._run_padded(self._pad_batch(blk), live,
                                          overrides)
            ids_out.append(np.asarray(ids[:live]))
            dists_out.append(np.asarray(dists[:live]))
        return np.concatenate(dists_out), np.concatenate(ids_out)

    # ------------------------------------------------------- request stream
    def submit(self, q, **overrides) -> int:
        """Queue one query; returns a ticket redeemable after flush().

        Keyword overrides are per-request query params (e.g. a traced
        ``n_probes``): requests sharing the same overrides are answered in
        the same micro-batch, and a traced knob never retraces.
        """
        # Validate caps HERE, before anything is queued: a bad override
        # must fail its own submit(), never a later flush() that would
        # jeopardise other clients' queued tickets.
        merged = dict(self.query_params)
        merged.update(overrides)
        self._check_caps(merged)
        ticket = self._next_ticket
        self._next_ticket += 1
        self._pending.append((ticket, np.asarray(q),
                              _override_key(overrides), overrides))
        if len(self._pending) >= self.batch_size:
            self.flush()
        return ticket

    def flush(self) -> None:
        """Answer every pending query in fixed-shape micro-batches,
        grouped by per-request overrides (submission order within each
        group is preserved).  Requests leave the queue only once their
        micro-batch succeeds, so a failure leaves the rest pending."""
        while self._pending:
            key0 = self._pending[0][2]
            chunk, rest = [], []
            for item in self._pending:
                if item[2] == key0 and len(chunk) < self.batch_size:
                    chunk.append(item)
                else:
                    rest.append(item)
            Qb = np.stack([q for _, q, _, _ in chunk])
            live = Qb.shape[0]
            dists, ids = self._run_padded(self._pad_batch(Qb), live,
                                          chunk[0][3])
            self._pending = rest
            ids = np.asarray(ids)
            dists = np.asarray(dists)
            for i, (ticket, _, _, _) in enumerate(chunk):
                self._results[ticket] = (dists[i], ids[i])

    def result(self, ticket: int) -> Tuple[np.ndarray, np.ndarray]:
        """(dists, ids) for a flushed ticket (pops it) — spec.search order."""
        if ticket not in self._results:
            raise KeyError(f"ticket {ticket} not flushed (or already read)")
        return self._results.pop(ticket)

    # ------------------------------------------------------------ autotuning
    def autotune(self, Q, gt_distances, *, knob_grid,
                 constraint, repetitions: int = 3):
        """Pick this engine's knob defaults from the constrained tuner.

        Runs :func:`repro.tune.grid_search` over ``knob_grid`` on the
        engine's own index state and, if a grid point satisfies the
        ``constraint`` (e.g. ``tune.Constraint.min_recall(0.9)``), adopts
        its knob values as the engine's ``query_params`` — all subsequent
        ``search()``/``submit()`` traffic serves at that operating point.

        Every swept knob must be traced-capable.  If its static ``max_*``
        cap is already pinned at or above the grid maximum (the usual
        deployment: caps fixed at engine construction), the tuned knobs
        are ordinary traced runtime values and adopting them triggers ZERO
        recompiles of the serving trace.  Otherwise the cap is raised to
        the grid maximum and the serving search re-jitted once.

        Returns the full :class:`repro.tune.TuneResult` (grid, Pareto set,
        chosen point); an infeasible constraint leaves the engine's
        ``query_params`` untouched (``result.best is None``).
        """
        from repro.tune import grid_search

        caps = dict(self.spec.traced_knobs)
        saved = (dict(self.query_params), self.traced_params, self._search)
        retrace_needed = False
        for knob, values in knob_grid.items():
            cap = caps.get(knob)
            if cap is None:
                raise ValueError(
                    f"{self.state.algo}: knob {knob!r} has no traced-cap "
                    f"treatment; tunable knobs: {sorted(caps)}")
            need = max(int(v) for v in values)
            have = self.query_params.get(cap)
            if have is None or int(have) < need:
                self.query_params[cap] = need
                retrace_needed = True
        traced = tuple(dict.fromkeys(
            list(self.traced_params) + list(knob_grid)))
        if retrace_needed or traced != self.traced_params:
            self.traced_params = traced
            self._search = self.spec.jit_search(traced=traced)
        fixed = {name: v for name, v in self.query_params.items()
                 if name not in knob_grid}
        result = grid_search(self.state, Q, gt_distances, k=self.k,
                             knob_grid=knob_grid, constraint=constraint,
                             repetitions=repetitions, query_params=fixed)
        if result.best is None:
            # infeasible: restore EVERYTHING — a raised cap (e.g. a fresh
            # max_scan) silently changes serving behaviour for knobs whose
            # value means "no limit", and the promise is untouched serving
            self.query_params, self.traced_params, self._search = saved
        else:
            self.query_params.update(result.best_params())
        return result

    # ------------------------------------------------------------- metadata
    @property
    def qps(self) -> float:
        t = self.stats["device_time_s"]
        return self.stats["queries"] / t if t > 0 else float("nan")

    def index_size_kb(self) -> float:
        return self.state.nbytes() / 1024.0

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"Engine({self.state.algo}, k={self.k}, "
                f"batch={self.batch_size}, params={self.query_params})")


def _is_plain(v) -> bool:
    """query params that survive a JSON round-trip (meshes etc. do not)."""
    return isinstance(v, (int, float, str, bool, type(None), tuple, list))


def _override_key(overrides: Dict[str, Any]) -> tuple:
    """Hashable grouping key for per-request overrides (scalar arrays
    collapse to their python value so e.g. jnp.int32(8) == 8)."""
    def norm(v):
        if np.ndim(v) == 0 and not isinstance(v, (str, bytes)):
            try:
                return np.asarray(v).item()
            except (TypeError, ValueError):
                pass
        return repr(v)
    return tuple(sorted((name, norm(v)) for name, v in overrides.items()))
