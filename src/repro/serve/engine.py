"""Batching serve engine over the functional index core.

The experiment loop calls algorithms per query set; a serving system sees an
open-ended stream of variable-size requests.  ``Engine`` turns an immutable
:class:`~repro.ann.functional.IndexState` into that serving surface:

  * **one trace** — the spec's pure ``search`` is jitted once for a fixed
    padded micro-batch shape ``[batch_size, d]``; every request batch is
    padded up to it, so no request size ever retraces;
  * **micro-batching** — ``submit()`` queues single queries and returns a
    :class:`Ticket` (a future: ``ticket.result()`` blocks, ``.done()``
    polls); ``search()`` streams arbitrarily large query sets through
    fixed-size micro-batches (device-resident end-to-end on the streaming
    distance+top-k path);
  * **deadlines** — ``submit(q, deadline_ms=...)`` bounds how stale an
    answer may be: a request whose deadline expires before its
    micro-batch runs is answered with
    :class:`~repro.serve.errors.DeadlineExceeded` instead of blocking or
    poisoning the batch it would have ridden in;
  * **pytree checkpointing** — ``save()``/``load()`` round-trip through
    :mod:`repro.serve.checkpoint` (versioned ``.npz``; stale/garbage
    files raise :class:`~repro.serve.checkpoint.CheckpointError`).

Query-time knobs ride along per engine (``query_params=``) and can be
overridden per ``search()`` call or per ``submit()``-ed request; a knob
whose static ``max_*`` cap partner is pinned in ``query_params`` is
automatically demoted to a traced runtime value (the spec's
``traced_knobs``), so per-request quality settings — e.g. IVF's
``n_probes`` under ``max_probes``, HNSW's ``ef`` under ``max_ef`` —
change behaviour *without* recompilation.

``Engine`` itself is synchronous and single-threaded (a flush happens on
the caller's thread when a batch fills, a ``ticket.result()`` forces one);
the SLO-aware background pump — timeout-based flush, admission control,
multi-tenant routing, latency percentiles — is
:class:`repro.serve.async_engine.AsyncEngine`, which drives Engines as its
per-tenant executors.
"""

from __future__ import annotations

import threading
import time
import warnings
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax

from repro.ann.functional import IndexState, get_functional
from repro.serve import checkpoint as _ckpt
# single-state helpers re-exported here for one release of back-compat —
# the canonical home (and the multi-tenant archive API) is
# repro.serve.checkpoint.
from repro.serve.checkpoint import (ARCHIVE_VERSION,          # noqa: F401
                                    CHECKPOINT_VERSION, CheckpointError,
                                    load_state, save_state)
from repro.serve.errors import DeadlineExceeded
from repro.serve import faults as _faults


class Ticket(int):
    """Future-style handle for one ``submit()``-ed request.

    ``ticket.result(timeout=)`` blocks until the request is answered and
    returns ``(dists [k], ids [k])`` (raising the request's typed error —
    e.g. :class:`DeadlineExceeded` — if it failed); ``ticket.done()``
    polls without blocking.  On the synchronous :class:`Engine`,
    ``result()`` flushes the queue itself; under
    :class:`~repro.serve.async_engine.AsyncEngine` it waits for the pump.

    Subclasses ``int`` (the submission sequence number) so one release of
    legacy call sites keeps working unchanged: ``eng.result(ticket)``,
    dict keys, and format strings all still see the bare-int ticket.
    That int protocol is the deprecation shim, not the API.
    """

    def __new__(cls, seq: int, resolver, *, deadline_s: Optional[float] = None,
                tenant: Optional[str] = None):
        t = super().__new__(cls, seq)
        t._resolver = resolver
        t._event = threading.Event()
        t._value: Optional[Tuple[np.ndarray, np.ndarray]] = None
        t._error: Optional[BaseException] = None
        t._submitted = time.perf_counter()
        t._deadline = (None if deadline_s is None
                       else t._submitted + deadline_s)
        t.tenant = tenant
        t.coverage: Optional[float] = None   # set at resolve; 1.0 = full
        return t

    @property
    def partial(self) -> bool:
        """True if the answer is degraded: it was computed over a subset
        of the index's shards (``coverage < 1``).  The merge is exact
        over the surviving shards — these are the best answers the live
        part of the index can give, flagged rather than hidden."""
        return self.coverage is not None and self.coverage < 1.0

    # ----------------------------------------------------------- client side
    def done(self) -> bool:
        """True once the request is answered (successfully or not)."""
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        """Block until answered; return ``(dists, ids)`` or raise the
        request's error.  ``timeout`` (seconds) bounds the wait itself
        and raises a plain :class:`TimeoutError` — distinct from
        :class:`DeadlineExceeded`, which means the *request* expired."""
        if not self._event.is_set():
            self._resolver._realise(self, timeout)
        if not self._event.is_set():
            raise TimeoutError(
                f"request {int(self)} still unanswered after {timeout}s "
                f"(the request itself is still in flight)")
        if self._error is not None:
            raise self._error
        return self._value

    # ------------------------------------------------------------ pump side
    def expired(self, now: Optional[float] = None) -> bool:
        if self._deadline is None:
            return False
        return (now if now is not None else time.perf_counter()) \
            > self._deadline

    def _resolve(self, dists: np.ndarray, ids: np.ndarray,
                 coverage: float = 1.0) -> None:
        self.coverage = float(coverage)
        self._value = (dists, ids)
        self._event.set()

    def _fail(self, error: BaseException) -> None:
        self._error = error
        self._event.set()

    def _time_out(self) -> None:
        waited = (time.perf_counter() - self._submitted) * 1e3
        budget = (self._deadline - self._submitted) * 1e3
        self._fail(DeadlineExceeded(
            f"request {int(self)} missed its {budget:.1f} ms deadline "
            f"({waited:.1f} ms elapsed before its micro-batch ran)"))


# --------------------------------------------------------------------------
# background compaction handle
# --------------------------------------------------------------------------

class Compaction:
    """Handle for one ``Engine.compact(background=True)`` run.

    ``join()`` waits for it; ``error`` is the rebuild's exception (None
    on success).  A failed background compaction never touches the
    serving state — the rebuild is pure and the swap only happens on
    success — so ``error`` is a report, not a recovery problem.
    """

    def __init__(self):
        self._event = threading.Event()
        self.error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    @property
    def ok(self) -> bool:
        """True once finished successfully (state swapped)."""
        return self._event.is_set() and self.error is None

    def join(self, timeout: Optional[float] = None) -> "Compaction":
        """Wait for the rebuild; raises ``TimeoutError`` if it is still
        running after ``timeout`` (the rebuild itself is NOT cancelled —
        it finishes or fails under the mutation lock either way)."""
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"background compaction still running after {timeout}s")
        return self

    def _finish(self, error: Optional[BaseException]) -> None:
        self.error = error
        self._event.set()


# --------------------------------------------------------------------------
# Engine
# --------------------------------------------------------------------------

class Engine:
    """Micro-batching query server over one device-resident IndexState.

    >>> eng = Engine.build("IVF", X, metric="euclidean",
    ...                    build_params={"n_clusters": 64},
    ...                    query_params={"n_probes": 8}, k=10)
    >>> dists, ids = eng.search(Q)          # any nq; fixed-shape batches
    >>> t = eng.submit(q); dists, ids = t.result()    # request path
    >>> eng.save("/tmp/ivf.ckpt"); eng2 = Engine.load("/tmp/ivf.ckpt")
    """

    def __init__(self, state: IndexState, *, k: int = 10,
                 batch_size: int = 256,
                 query_params: Optional[Dict[str, Any]] = None,
                 traced_params: Tuple[str, ...] = ()):
        self.spec = get_functional(state.algo)
        self.state = state
        self.k = int(k)
        self.batch_size = int(batch_size)
        self.query_params = self.spec.default_query_params()
        self.query_params.update(query_params or {})
        # ``traced_params`` demotes spec-static knobs to runtime values —
        # e.g. IVF's n_probes under a pinned max_probes cap: the knob then
        # sweeps recall/QPS with zero retraces.  Knobs whose static cap
        # partner is pinned in ``query_params`` are demoted automatically.
        traced = list(traced_params)
        for knob, cap in self.spec.traced_knobs:
            if knob not in traced and self.query_params.get(cap) is not None:
                traced.append(knob)
        # A traced knob whose value is None (= "no limit", e.g. IVF's
        # ``scan``) is pinned to its cap: in traced mode the two are
        # semantically identical, but None and int trace DIFFERENTLY
        # (pytree structure), and serving must keep one trace across
        # later integer updates — e.g. adopting an autotuned value.
        for knob, cap in self.spec.traced_knobs:
            if (knob in traced and self.query_params.get(knob) is None
                    and self.query_params.get(cap) is not None):
                self.query_params[knob] = int(self.query_params[cap])
        self.traced_params = tuple(traced)
        self._search = self.spec.jit_search(traced=self.traced_params)
        self._pending: list = []    # (Ticket, np.ndarray [d], key, overrides)
        self._results: Dict[int, Ticket] = {}   # legacy result() buffer
        self._next_ticket = 0
        # serialises insert/delete/compact; the serving path never takes it
        # (state swaps are a single attribute write, _run_padded reads
        # self.state exactly once per batch)
        self._mutate_lock = threading.Lock()
        # outstanding background-compaction handles (close() drains them)
        self._compactions: list = []
        # sharded states always thread a [n_shards] keep-mask through the
        # serving trace (all-True normally) so a degraded call — some
        # shards masked by the fault layer — rides the SAME compiled
        # program: zero retraces under faults, identity without them
        shard_axes = state.static.get("shard_axes")
        self._n_shards = (int(state.stat("n_shards")) if shard_axes
                          else 0)
        self._shard_all_ok = (np.ones(self._n_shards, bool)
                              if self._n_shards else None)
        self.last_coverage = 1.0     # min coverage of the last search()
        self.stats = {"queries": 0, "batches": 0, "padded": 0,
                      "device_time_s": 0.0, "inserts": 0, "deletes": 0,
                      "compactions": 0, "compaction_failures": 0,
                      "degraded": 0}

    # ---------------------------------------------------------- constructors
    @classmethod
    def build(cls, algo: str, X, *, metric: str,
              build_params: Optional[Dict[str, Any]] = None,
              **engine_kwargs) -> "Engine":
        spec = get_functional(algo)
        state = spec.build(X, metric=metric, **(build_params or {}))
        return cls(state, **engine_kwargs)

    @classmethod
    def from_checkpoint_entry(cls, state: IndexState, extra: dict,
                              **overrides) -> "Engine":
        """Engine from one ``checkpoint.load`` entry (state + extras).

        Sharded states carry their mesh *recipe* in ``static``, so a
        checkpoint written on one host serves on another: if the recipe
        fits the visible devices it is used as-is, otherwise the state is
        resharded onto all local devices (``ensure_servable``)."""
        from repro.dist.shard_state import ensure_servable

        state = ensure_servable(state)
        kwargs = {"k": extra.get("k", 10),
                  "batch_size": extra.get("batch_size", 256),
                  "query_params": extra.get("query_params") or {},
                  "traced_params": tuple(extra.get("traced_params") or ())}
        kwargs.update(overrides)
        return cls(state, **kwargs)

    @classmethod
    def load(cls, path, **overrides) -> "Engine":
        state, extra = _ckpt.load(path).only
        return cls.from_checkpoint_entry(state, extra, **overrides)

    def _ckpt_extra(self) -> dict:
        return {
            "k": self.k, "batch_size": self.batch_size,
            "query_params": {k: v for k, v in self.query_params.items()
                             if _is_plain(v)},
            "traced_params": list(self.traced_params),
        }

    def save(self, path) -> Path:
        return _ckpt.save(path, self.state, extra=self._ckpt_extra())

    # -------------------------------------------------------------- serving
    def _check_caps(self, params) -> None:
        """Reject knob values above their static cap: the traced search
        would silently clamp them (shapes are fixed at trace time), which
        must not masquerade as the requested quality setting."""
        for knob, cap in self.spec.traced_knobs:
            cap_v, knob_v = params.get(cap), params.get(knob)
            if cap_v is None or knob_v is None:
                continue
            try:
                knob_i = int(np.asarray(knob_v))
            except (TypeError, ValueError):
                continue
            if knob_i > int(cap_v):
                raise ValueError(
                    f"{knob}={knob_i} exceeds the engine's static "
                    f"{cap}={int(cap_v)} (the trace would clamp it); "
                    f"rebuild the Engine with a larger {cap}")

    def _run_padded(self, Qb: np.ndarray, n_live: int, overrides):
        """One fixed-shape device call: Qb is already [batch_size, d].

        Returns ``(dists, ids, coverage)``; coverage < 1 means the state
        is sharded and the fault layer masked some shards for this batch
        (the answers are exact over the surviving shards).  The fault
        hook runs HERE, host-side, because ``self._search`` is the outer
        jit — inside it the hook in ``sharded_search`` sees tracers and
        defers to the mask we pass in."""
        params = dict(self.query_params)
        params.update(overrides)
        self._check_caps(params)
        coverage = 1.0
        if self._n_shards:
            mask = _faults.shard_events(self._n_shards)  # raises/sleeps per plan
            if mask is None:
                mask = self._shard_all_ok
            else:
                from repro.dist.shard_state import shard_coverage
                coverage = shard_coverage(self.state, mask)
                self.stats["degraded"] += n_live
            params["shard_ok"] = mask
        t0 = time.perf_counter()
        dists, ids = self._search(self.state, Qb, k=self.k, **params)
        ids = jax.block_until_ready(ids)
        self.stats["device_time_s"] += time.perf_counter() - t0
        self.stats["batches"] += 1
        self.stats["queries"] += n_live
        self.stats["padded"] += Qb.shape[0] - n_live
        return dists, ids, coverage

    def _pad_batch(self, Q: np.ndarray) -> np.ndarray:
        pad = self.batch_size - Q.shape[0]
        if pad == 0:
            return Q
        return np.concatenate(
            [Q, np.zeros((pad,) + Q.shape[1:], Q.dtype)], axis=0)

    def search(self, Q, **overrides) -> Tuple[np.ndarray, np.ndarray]:
        """Answer a query set of any size via fixed-shape micro-batches.

        Returns ``(dists [nq, k], ids [nq, k])`` as numpy arrays — the same
        order as every functional ``spec.search``.  Keyword overrides are
        per-call query params (a traced knob changes behaviour with no
        retrace; a static knob retraces once per value).
        """
        Q = np.asarray(Q)
        nq = Q.shape[0]
        if nq == 0:
            return (np.empty((0, self.k), np.float32),
                    np.empty((0, self.k), np.int32))
        ids_out, dists_out = [], []
        self.last_coverage = 1.0
        for s in range(0, nq, self.batch_size):
            blk = Q[s:s + self.batch_size]
            live = blk.shape[0]
            dists, ids, cov = self._run_padded(self._pad_batch(blk), live,
                                               overrides)
            self.last_coverage = min(self.last_coverage, cov)
            ids_out.append(np.asarray(ids[:live]))
            dists_out.append(np.asarray(dists[:live]))
        return np.concatenate(dists_out), np.concatenate(ids_out)

    # ------------------------------------------------------------- mutation
    # All three swap ``self.state`` with a single attribute write — the
    # serving path (_run_padded, and AsyncEngine's pump through it) reads
    # the attribute exactly once per micro-batch, so a concurrent query
    # sees either the old state or the new one, never a mix, and no ticket
    # is ever dropped.  Mutations serialise on ``_mutate_lock``.

    def insert(self, X_new, ids=None, *, auto_compact: bool = True):
        """Append rows to a mutable index (delta-buffer write, no retrace).

        Returns the assigned global ids.  With ``auto_compact`` (default)
        a full delta buffer — or one past the state's
        ``compact_threshold`` occupancy after the insert — triggers
        :meth:`compact` inline; with ``auto_compact=False`` a full buffer
        raises :class:`~repro.mutate.DeltaFull` for the caller to handle
        (e.g. to schedule compaction off the request path).
        """
        from repro import mutate

        with self._mutate_lock:
            try:
                state, new_ids = mutate.insert(self.state, X_new, ids)
            except mutate.DeltaFull:
                if not auto_compact:
                    raise
                self.state = mutate.compact(self.state)
                self.stats["compactions"] += 1
                state, new_ids = mutate.insert(self.state, X_new, ids)
            self.state = state
            self.stats["inserts"] += len(new_ids)
            if auto_compact and mutate.delta_fraction(state) \
                    >= state.stat("compact_threshold"):
                self.state = mutate.compact(self.state)
                self.stats["compactions"] += 1
        return new_ids

    def delete(self, ids) -> None:
        """Tombstone global ids (masked, not compacted — zero retraces)."""
        from repro import mutate

        with self._mutate_lock:
            self.state = mutate.delete(self.state, ids)
            self.stats["deletes"] += int(np.asarray(ids).reshape(-1).size)

    def compact(self, *, background: bool = False,
                on_done=None) -> Optional[Compaction]:
        """Fold the delta into a fresh main index and hot-swap it in.

        In-flight and concurrently submitted requests are never dropped:
        the rebuild happens off to the side and the swap is one attribute
        write (see the section comment).  MutableBruteForce swaps preserve
        the serving trace (same shapes); MutableIVF re-clusters and
        retraces once.

        ``background=True`` runs the rebuild on its own thread — still
        under the mutation lock (inserts/deletes queue behind it; the
        serving path never blocks) — and returns a :class:`Compaction`
        handle immediately.  On success the new state hot-swaps in; on
        failure (including an injected
        :class:`~repro.serve.errors.CompactionError`) the serving state
        is untouched, ``stats["compaction_failures"]`` increments, and
        the error lands on the handle (and ``on_done(error)``, if given)
        — never on the serving threads.  A foreground failure raises.
        """
        from repro import mutate

        if not background:
            with self._mutate_lock:
                try:
                    new_state = mutate.compact(self.state)
                except BaseException:
                    self.stats["compaction_failures"] += 1
                    raise
                self.state = new_state
                self.stats["compactions"] += 1
            if on_done is not None:
                on_done(None)
            return None

        handle = Compaction()
        self._compactions.append(handle)

        def run():
            error = None
            try:
                with self._mutate_lock:
                    new_state = mutate.compact(self.state)
                    self.state = new_state
                    self.stats["compactions"] += 1
            except BaseException as e:          # noqa: BLE001
                error = e
                self.stats["compaction_failures"] += 1
            handle._finish(error)
            if on_done is not None:
                on_done(error)

        threading.Thread(target=run, name="repro-serve-compact",
                         daemon=True).start()
        return handle

    def join_compactions(self, timeout: Optional[float] = None) -> bool:
        """Drain outstanding background compactions (True if all
        finished within ``timeout``).  Finished handles are pruned."""
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)
        for handle in list(self._compactions):
            remaining = (None if deadline is None
                         else max(0.0, deadline - time.perf_counter()))
            if not handle._event.wait(remaining):
                return False
        self._compactions = [h for h in self._compactions if not h.done()]
        return True

    # ------------------------------------------------------- request stream
    def submit(self, q, *, deadline_ms: Optional[float] = None,
               **overrides) -> Ticket:
        """Queue one query; returns a :class:`Ticket` future.

        ``ticket.result()`` blocks until the answer is ready (flushing the
        queue if needed); a full batch flushes immediately.  Keyword
        overrides are per-request query params (e.g. a traced
        ``n_probes``): requests sharing the same overrides are answered in
        the same micro-batch, and a traced knob never retraces.
        ``deadline_ms`` bounds staleness: if the deadline passes before
        the request's micro-batch runs, the ticket resolves to
        :class:`DeadlineExceeded` instead of a late answer — and the rest
        of its batch is answered normally.
        """
        # Validate caps HERE, before anything is queued: a bad override
        # must fail its own submit(), never a later flush() that would
        # jeopardise other clients' queued tickets.
        merged = dict(self.query_params)
        merged.update(overrides)
        self._check_caps(merged)
        ticket = Ticket(self._next_ticket, self,
                        deadline_s=None if deadline_ms is None
                        else deadline_ms / 1e3)
        self._next_ticket += 1
        self._pending.append((ticket, np.asarray(q),
                              _override_key(overrides), overrides))
        if len(self._pending) >= self.batch_size:
            self.flush()
        return ticket

    def flush(self) -> None:
        """Answer every pending query in fixed-shape micro-batches,
        grouped by per-request overrides (submission order within each
        group is preserved).  Deadline-expired requests are answered as
        :class:`DeadlineExceeded` without riding in (or delaying) the
        batch.  Requests leave the queue only once their micro-batch
        succeeds, so a failure leaves the rest pending."""
        while self._pending:
            key0 = self._pending[0][2]
            chunk, rest = [], []
            for item in self._pending:
                if item[2] == key0 and len(chunk) < self.batch_size:
                    chunk.append(item)
                else:
                    rest.append(item)
            now = time.perf_counter()
            live_items = []
            for item in chunk:
                if item[0].expired(now):
                    item[0]._time_out()
                    self._results[int(item[0])] = item[0]
                else:
                    live_items.append(item)
            if not live_items:
                self._pending = rest
                continue
            Qb = np.stack([q for _, q, _, _ in live_items])
            live = Qb.shape[0]
            dists, ids, cov = self._run_padded(self._pad_batch(Qb), live,
                                               live_items[0][3])
            self._pending = rest
            ids = np.asarray(ids)
            dists = np.asarray(dists)
            for i, (ticket, _, _, _) in enumerate(live_items):
                ticket._resolve(dists[i], ids[i], coverage=cov)
                self._results[int(ticket)] = ticket

    def _realise(self, ticket: Ticket, timeout) -> None:
        """Ticket.result() hook: the sync engine answers its own queue."""
        self.flush()

    def result(self, ticket) -> Tuple[np.ndarray, np.ndarray]:
        """(deprecated) ``(dists, ids)`` for a flushed ticket (pops it).

        The pre-ISSUE-6 redemption path: kept for one release so bare-int
        call sites keep working.  New code holds the :class:`Ticket` from
        ``submit()`` and calls ``ticket.result()``.
        """
        warnings.warn("Engine.result(ticket) is deprecated; call "
                      "ticket.result() on the Ticket submit() returned",
                      DeprecationWarning, stacklevel=2)
        if int(ticket) not in self._results:
            raise KeyError(f"ticket {int(ticket)} not flushed "
                           f"(or already read)")
        t = self._results.pop(int(ticket))
        if t._error is not None:
            raise t._error
        return t._value

    # ------------------------------------------------------------ autotuning
    def autotune(self, Q, gt_distances, *, knob_grid,
                 constraint, repetitions: int = 3):
        """Pick this engine's knob defaults from the constrained tuner.

        Runs :func:`repro.tune.grid_search` over ``knob_grid`` on the
        engine's own index state and, if a grid point satisfies the
        ``constraint`` (e.g. ``tune.Constraint.min_recall(0.9)``), adopts
        its knob values as the engine's ``query_params`` — all subsequent
        ``search()``/``submit()`` traffic serves at that operating point.

        Every swept knob must be traced-capable.  If its static ``max_*``
        cap is already pinned at or above the grid maximum (the usual
        deployment: caps fixed at engine construction), the tuned knobs
        are ordinary traced runtime values and adopting them triggers ZERO
        recompiles of the serving trace.  Otherwise the cap is raised to
        the grid maximum and the serving search re-jitted once.

        Returns the full :class:`repro.tune.TuneResult` (grid, Pareto set,
        chosen point); an infeasible constraint leaves the engine's
        ``query_params`` untouched (``result.best is None``).
        """
        from repro.tune import grid_search

        caps = dict(self.spec.traced_knobs)
        saved = (dict(self.query_params), self.traced_params, self._search)
        retrace_needed = False
        for knob, values in knob_grid.items():
            cap = caps.get(knob)
            if cap is None:
                raise ValueError(
                    f"{self.state.algo}: knob {knob!r} has no traced-cap "
                    f"treatment; tunable knobs: {sorted(caps)}")
            need = max(int(v) for v in values)
            have = self.query_params.get(cap)
            if have is None or int(have) < need:
                self.query_params[cap] = need
                retrace_needed = True
        traced = tuple(dict.fromkeys(
            list(self.traced_params) + list(knob_grid)))
        if retrace_needed or traced != self.traced_params:
            self.traced_params = traced
            self._search = self.spec.jit_search(traced=traced)
        fixed = {name: v for name, v in self.query_params.items()
                 if name not in knob_grid}
        result = grid_search(self.state, Q, gt_distances, k=self.k,
                             knob_grid=knob_grid, constraint=constraint,
                             repetitions=repetitions, query_params=fixed)
        if result.best is None:
            # infeasible: restore EVERYTHING — a raised cap (e.g. a fresh
            # max_scan) silently changes serving behaviour for knobs whose
            # value means "no limit", and the promise is untouched serving
            self.query_params, self.traced_params, self._search = saved
        else:
            self.query_params.update(result.best_params())
        return result

    # ------------------------------------------------------------- metadata
    @property
    def qps(self) -> float:
        t = self.stats["device_time_s"]
        return self.stats["queries"] / t if t > 0 else float("nan")

    def index_size_kb(self) -> float:
        return self.state.nbytes() / 1024.0

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"Engine({self.state.algo}, k={self.k}, "
                f"batch={self.batch_size}, params={self.query_params})")


def _is_plain(v) -> bool:
    """query params that survive a JSON round-trip (meshes etc. do not)."""
    return isinstance(v, (int, float, str, bool, type(None), tuple, list))


def _override_key(overrides: Dict[str, Any]) -> tuple:
    """Hashable grouping key for per-request overrides (scalar arrays
    collapse to their python value so e.g. jnp.int32(8) == 8)."""
    def norm(v):
        if np.ndim(v) == 0 and not isinstance(v, (str, bytes)):
            try:
                return np.asarray(v).item()
            except (TypeError, ValueError):
                pass
        return repr(v)
    return tuple(sorted((name, norm(v)) for name, v in overrides.items()))
