"""SLO-aware async serving tier: a background pump over resident Engines.

The synchronous :class:`~repro.serve.engine.Engine` batches well but has
no latency story: ``submit()`` queues and *somebody* must ``flush()``.
This module adds the production-shaped front end the open-stream workload
needs — one process, one pump thread, many resident indexes:

    client threads ──submit()──►  bounded queue  ──►  pump thread
         ▲                        (admission ctl)      │ groups by
         │ Ticket.result()                             │ (tenant, overrides)
         └────────── tickets resolved ◄── micro-batch ─┘ fixed shape,
                     (or DeadlineExceeded)               ONE jit trace

  * **timeout-based flush** — the pump fires a micro-batch on whichever
    comes first of ``max_batch`` queued requests or the oldest request
    having waited ``max_wait_ms``; latency is bounded by design, not by
    caller discipline.
  * **per-request deadlines** — an admitted request whose deadline passes
    before its batch runs is answered as
    :class:`~repro.serve.errors.DeadlineExceeded` (swept out *without*
    delaying or poisoning the batch its group rides in).
  * **admission control** — the queue is bounded (``max_queue``); at
    capacity ``submit()`` raises
    :class:`~repro.serve.errors.AdmissionError` immediately.  Overload
    sheds load at the door instead of growing an unbounded queue in which
    every deadline dies.
  * **multi-tenant serving** — several resident
    :class:`~repro.ann.functional.IndexState`\\ s (datasets / quality
    tiers) behind one pump: ``submit(q, tenant="west")`` routes to that
    tenant's Engine and its single fixed-shape trace.  One archive
    checkpoints all of them (:mod:`repro.serve.checkpoint`).
  * **latency accounting** — every request's submit-to-answer latency
    lands in a :class:`~repro.serve.metrics.ServeMetrics` histogram
    (p50/p95/p99 per tenant and overall), the numbers the
    ``bench_serving`` CI gate enforces.
  * **fault tolerance** — transient shard faults retry with exponential
    backoff and deterministic jitter
    (:class:`~repro.serve.retry.RetryPolicy`); degraded sharded answers
    resolve with ``ticket.coverage < 1`` instead of failing; and a pump
    supervisor fails every outstanding ticket with
    :class:`~repro.serve.errors.EngineDegraded` if the pump thread ever
    dies, so ``ticket.result()`` can never hang on a dead pump.

The pump is a plain daemon thread (the device work releases the GIL
inside jax, and a thread needs no event-loop plumbing in callers); each
tenant's Engine keeps its one fixed-padded-trace + override-grouped
micro-batch substrate, so the whole tier serves mixed per-request knob
overrides with ZERO retraces.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Dict, Mapping, Optional, Tuple, Union

import numpy as np

from repro.serve import checkpoint as _ckpt
from repro.serve import faults as _faults
from repro.serve.engine import Engine, Ticket, _override_key
from repro.serve.errors import (AdmissionError, EngineClosed,
                                EngineDegraded, RetriesExhausted)
from repro.serve.metrics import ServeMetrics
from repro.serve.retry import RetryPolicy

#: tenant name used when an AsyncEngine wraps a single Engine.
DEFAULT_TENANT = "default"


class _Request:
    __slots__ = ("ticket", "q", "tenant", "key", "overrides")

    def __init__(self, ticket: Ticket, q: np.ndarray, tenant: str,
                 key: tuple, overrides: dict):
        self.ticket = ticket
        self.q = q
        self.tenant = tenant
        self.key = key
        self.overrides = overrides


class AsyncEngine:
    """Background micro-batch pump over one or more resident Engines.

    >>> eng = Engine.build("IVF", X, metric="euclidean",
    ...                    build_params={"n_clusters": 64},
    ...                    query_params={"n_probes": 8}, k=10)
    >>> with AsyncEngine(eng, max_wait_ms=5, max_queue=1024) as srv:
    ...     t = srv.submit(q, deadline_ms=50)
    ...     dists, ids = t.result()
    ...     srv.metrics.percentile(95)        # seconds, includes queueing

    ``engines`` is one :class:`Engine` or a mapping ``tenant -> Engine``;
    requests route by the ``tenant=`` keyword of :meth:`submit`.  The
    pump starts immediately and runs until :meth:`close` (or context
    exit), which stops admission and DRAINS: every already-admitted
    ticket is answered (or deadline-timed-out) before the pump exits.
    """

    def __init__(self, engines: Union[Engine, Mapping[str, Engine]], *,
                 max_wait_ms: float = 5.0,
                 max_batch: Optional[int] = None,
                 max_queue: int = 1024,
                 default_deadline_ms: Optional[float] = None,
                 metrics: Optional[ServeMetrics] = None,
                 retry: Optional[RetryPolicy] = None):
        if isinstance(engines, Engine):
            engines = {DEFAULT_TENANT: engines}
        self.engines: Dict[str, Engine] = dict(engines)
        if not self.engines:
            raise ValueError("AsyncEngine needs at least one resident "
                             "Engine (got an empty mapping)")
        self.max_wait_s = float(max_wait_ms) / 1e3
        self.max_queue = int(max_queue)
        # flush threshold per tenant: the tenant's fixed micro-batch shape
        # caps it (a bigger batch can't ride one device call anyway)
        self._flush_at = {
            t: min(int(max_batch), e.batch_size) if max_batch else
            e.batch_size for t, e in self.engines.items()}
        self.default_deadline_s = (None if default_deadline_ms is None
                                   else float(default_deadline_ms) / 1e3)
        self.metrics = metrics if metrics is not None else ServeMetrics()
        # transient faults (ShardFault etc.) retry under this policy;
        # RetryPolicy(max_attempts=1) disables retrying
        self.retry = retry if retry is not None else RetryPolicy()
        self.last_service_s = 0.0     # most recent micro-batch device+host
        self._queue: deque = deque()
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._closed = False
        self._degraded: Optional[BaseException] = None
        # the batch the pump popped but has not resolved yet — only the
        # pump thread touches it, and the supervisor (which also runs on
        # the pump thread, as its last act) fails it on pump death so no
        # admitted ticket can ever be left hanging
        self._inflight: list = []
        self._seq = 0
        self._pump = threading.Thread(target=self._pump_main,
                                      name="repro-serve-pump", daemon=True)
        self._pump.start()

    # ------------------------------------------------------------ lifecycle
    def __enter__(self) -> "AsyncEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self, timeout: Optional[float] = 30.0) -> None:
        """Stop admitting requests and drain the queue.

        Every ticket admitted before close() is resolved — answered, or
        :class:`DeadlineExceeded` if its deadline lapses during the drain
        — before the pump thread exits.  Any in-flight background
        compactions are joined too, so no daemon rebuild thread outlives
        the tier.  Idempotent."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        self._pump.join(timeout)
        for eng in self.engines.values():
            eng.join_compactions(timeout)

    @property
    def closed(self) -> bool:
        return self._closed

    def qsize(self) -> int:
        """Current queue depth (admitted, not yet batched)."""
        with self._lock:
            return len(self._queue)

    # ------------------------------------------------------------- tenants
    @property
    def tenants(self) -> Tuple[str, ...]:
        return tuple(sorted(self.engines))

    def _resolve_tenant(self, tenant: Optional[str]) -> str:
        if tenant is None:
            if len(self.engines) == 1:
                return next(iter(self.engines))
            raise ValueError(
                f"this AsyncEngine serves {len(self.engines)} tenants "
                f"{self.tenants}; pass tenant=")
        if tenant not in self.engines:
            raise ValueError(f"unknown tenant {tenant!r}; resident: "
                             f"{self.tenants}")
        return tenant

    # ------------------------------------------------------------ submission
    def submit(self, q, *, tenant: Optional[str] = None,
               deadline_ms: Optional[float] = None, **overrides) -> Ticket:
        """Admit one query; returns a :class:`Ticket` future.

        Raises :class:`AdmissionError` when the queue is at ``max_queue``
        (the request is NOT queued), :class:`EngineClosed` after
        :meth:`close`, and ``ValueError`` for unknown tenants or knob
        overrides above their static cap — all *before* anything is
        admitted, so a bad request can never poison queued ones.
        """
        name = self._resolve_tenant(tenant)
        eng = self.engines[name]
        merged = dict(eng.query_params)
        merged.update(overrides)
        eng._check_caps(merged)
        deadline_s = (self.default_deadline_s if deadline_ms is None
                      else deadline_ms / 1e3)
        q = np.asarray(q)
        with self._cond:
            if self._degraded is not None:
                raise EngineDegraded(
                    "the pump thread died "
                    f"({type(self._degraded).__name__}: {self._degraded}); "
                    "this AsyncEngine no longer serves — rebuild it "
                    "(outstanding tickets were failed, not hung)"
                ) from self._degraded
            if self._closed:
                raise EngineClosed("submit() after close(); the pump no "
                                   "longer admits requests")
            if len(self._queue) >= self.max_queue:
                self.metrics.count("rejected", tenant=name)
                raise AdmissionError(
                    f"queue depth {self.max_queue} reached "
                    f"(tenant {name!r}); the request was rejected, not "
                    f"queued — retry with backoff or raise max_queue")
            ticket = Ticket(self._seq, self, deadline_s=deadline_s,
                            tenant=name)
            self._seq += 1
            self._queue.append(_Request(ticket, q, name,
                                        _override_key(overrides), overrides))
            self.metrics.count("submitted", tenant=name)
            self._cond.notify()
        return ticket

    def search(self, Q, *, tenant: Optional[str] = None,
               deadline_ms: Optional[float] = None,
               timeout: Optional[float] = 60.0,
               **overrides) -> Tuple[np.ndarray, np.ndarray]:
        """Convenience closed-loop path: submit every row of ``Q`` and
        gather ``(dists [nq, k], ids [nq, k])``.  Mostly for parity tests
        and warmup — an open-loop client holds the Tickets itself.
        (``nq`` must fit the admission bound; rows past ``max_queue``
        would be rejected.)"""
        tickets = [self.submit(q, tenant=tenant, deadline_ms=deadline_ms,
                               **overrides) for q in np.asarray(Q)]
        pairs = [t.result(timeout=timeout) for t in tickets]
        return (np.stack([d for d, _ in pairs]),
                np.stack([i for _, i in pairs]))

    def _realise(self, ticket: Ticket, timeout) -> None:
        """Ticket.result() hook: wait for the pump (never run its work
        on the client thread — ordering belongs to the pump)."""
        ticket._event.wait(timeout)

    # ------------------------------------------------------------ pump loop
    def _due_locked(self, now: float) -> bool:
        if not self._queue:
            return False
        head = self._queue[0]
        if len(self._queue) >= self._flush_at[head.tenant]:
            return True
        if now - head.ticket._submitted >= self.max_wait_s:
            return True
        return any(r.ticket.expired(now) for r in self._queue)

    def _wake_in_locked(self, now: float) -> Optional[float]:
        """Seconds until the next flush/expiry is due (None: idle)."""
        if not self._queue:
            return None
        due = self._queue[0].ticket._submitted + self.max_wait_s
        for r in self._queue:
            d = r.ticket._deadline
            if d is not None and d < due:
                due = d
        return max(due - now, 1e-4)

    def _pop_expired_locked(self, now: float) -> list:
        expired, keep = [], deque()
        for r in self._queue:
            (expired if r.ticket.expired(now) else keep).append(r)
        self._queue = keep
        return expired

    def _pop_batch_locked(self) -> list:
        """Oldest request's (tenant, overrides) group, up to its flush
        threshold, submission order preserved; the rest stay queued."""
        head = self._queue[0]
        cap = self._flush_at[head.tenant]
        take, keep = [], deque()
        for r in self._queue:
            if (len(take) < cap and r.tenant == head.tenant
                    and r.key == head.key):
                take.append(r)
            else:
                keep.append(r)
        self._queue = keep
        return take

    def _pump_main(self) -> None:
        """Pump thread entry: supervise :meth:`_pump_loop`.

        If the loop ever escapes with an exception (a bug, or an injected
        :class:`~repro.serve.faults.PumpFault`), the tier must not hang
        every outstanding ``ticket.result()`` forever — the supervisor
        marks the engine degraded and fails every admitted-but-unresolved
        ticket with :class:`EngineDegraded` before the thread exits."""
        try:
            self._pump_loop()
        except BaseException as e:                  # noqa: BLE001
            self._mark_degraded(e)

    def _mark_degraded(self, cause: BaseException) -> None:
        """Fail every outstanding ticket and refuse future admission.

        Runs on the (dying) pump thread, so ``_inflight`` — touched only
        by the pump — needs no lock; the queue sweep happens under
        ``_cond`` so no concurrent ``submit()`` can slip a ticket in
        between the sweep and the degraded flag."""
        with self._cond:
            self._degraded = cause
            queued = list(self._queue)
            self._queue = deque()
            self._cond.notify_all()
        victims = self._inflight + queued
        self._inflight = []
        err = EngineDegraded(
            f"pump thread died: {type(cause).__name__}: {cause}")
        err.__cause__ = cause
        for r in victims:
            if not r.ticket.done():
                r.ticket._fail(err)
                self.metrics.count("failed", tenant=r.tenant)

    def _pump_loop(self) -> None:
        while True:
            with self._cond:
                while not self._closed \
                        and not self._due_locked(time.perf_counter()):
                    self._cond.wait(
                        timeout=self._wake_in_locked(time.perf_counter()))
                now = time.perf_counter()
                expired = self._pop_expired_locked(now)
                batch = self._pop_batch_locked() if self._queue else []
                done = self._closed and not self._queue \
                    and not batch and not expired
            for r in expired:
                r.ticket._time_out()
                self.metrics.count("timed_out", tenant=r.tenant)
            if batch:
                self._inflight = batch
                # deliberately OUTSIDE _serve's try: an injected pump
                # death must kill the loop (exercising the supervisor),
                # not be absorbed as a per-batch failure
                _faults.pump_tick()
                self._serve(batch)
                self._inflight = []
            if done:
                return

    def _retry_viable(self, live: list, delay_s: float,
                      now: float) -> bool:
        """Another attempt is worth it only if some live ticket could
        still meet its deadline after sleeping ``delay_s``."""
        return any(r.ticket._deadline is None
                   or r.ticket._deadline > now + delay_s for r in live)

    def _serve(self, batch: list) -> None:
        """One micro-batch through the tenant's fixed-shape trace.

        Transient faults (:class:`~repro.serve.errors.TransientFault`,
        e.g. a shard raising mid-search) retry under ``self.retry`` with
        exponential backoff and deterministic jitter, but only while some
        live ticket's deadline can still be met; exhausted budgets fail
        the batch's tickets with :class:`RetriesExhausted`."""
        eng = self.engines[batch[0].tenant]
        t0 = time.perf_counter()
        # re-check deadlines at service time (they may have lapsed between
        # the readiness check and here); expired requests are answered as
        # timeouts and the batch shrinks around them — never poisoned
        live = []
        for r in batch:
            if r.ticket.expired(t0):
                r.ticket._time_out()
                self.metrics.count("timed_out", tenant=r.tenant)
            else:
                live.append(r)
        if not live:
            return
        tenant = live[0].tenant
        token = int(live[0].ticket)   # keys the deterministic jitter
        attempt = 0
        while True:
            attempt += 1
            try:
                Qb = np.stack([r.q for r in live])
                dists, ids, coverage = eng._run_padded(
                    eng._pad_batch(Qb), len(live), live[0].overrides)
                dists, ids = np.asarray(dists), np.asarray(ids)
                break
            except Exception as e:                  # noqa: BLE001
                if self.retry.retryable(e) \
                        and attempt < self.retry.max_attempts:
                    delay = self.retry.backoff_s(attempt, token=token)
                    if self._retry_viable(live, delay,
                                          time.perf_counter()):
                        self.metrics.count("retried", tenant=tenant)
                        time.sleep(delay)
                        continue
                    cause = e
                    e = RetriesExhausted(
                        f"attempt {attempt}/{self.retry.max_attempts} "
                        f"failed ({type(cause).__name__}: {cause}) and no "
                        f"live deadline survives the {delay * 1e3:.2f} ms "
                        f"backoff")
                    e.__cause__ = cause
                elif self.retry.retryable(e):
                    cause = e
                    e = RetriesExhausted(
                        f"all {self.retry.max_attempts} attempts failed; "
                        f"last: {type(cause).__name__}: {cause}")
                    e.__cause__ = cause
                # the pump must survive a poisoned batch (e.g. a bad query
                # vector): fail ITS tickets, keep serving everyone else
                for r in live:
                    r.ticket._fail(e)
                    self.metrics.count("failed", tenant=r.tenant)
                return
        done = time.perf_counter()
        self.last_service_s = done - t0
        self.metrics.count("batches", tenant=tenant)
        self.metrics.count("padded", eng.batch_size - len(live),
                           tenant=tenant)
        if coverage < 1.0:
            self.metrics.count("degraded", len(live), tenant=tenant)
        for i, r in enumerate(live):
            r.ticket._resolve(dists[i], ids[i], coverage=coverage)
            self.metrics.count("served", tenant=r.tenant)
            self.metrics.observe(done - r.ticket._submitted, tenant=r.tenant)
            self.metrics.observe_coverage(coverage, tenant=r.tenant)

    # ------------------------------------------------------------- mutation
    # Thin passthroughs to the tenant Engine's mutation surface.  They are
    # pump-safe by construction: Engine mutations swap ``eng.state`` with
    # one attribute write and ``_serve`` reads it exactly once per
    # micro-batch (via ``eng._run_padded``), so a compaction racing the
    # pump resolves every admitted ticket against either the old or the
    # new state — never an error, never a dropped ticket
    # (tests/test_serving.py hammers submit() against compact()).

    def insert(self, X_new, ids=None, *, tenant: Optional[str] = None,
               **kwargs):
        """Append rows to a tenant's mutable index (delta-buffer write)."""
        return self.engines[self._resolve_tenant(tenant)].insert(
            X_new, ids, **kwargs)

    def delete(self, ids, *, tenant: Optional[str] = None) -> None:
        """Tombstone global ids on a tenant's mutable index."""
        self.engines[self._resolve_tenant(tenant)].delete(ids)

    def compact(self, *, tenant: Optional[str] = None,
                background: bool = False):
        """Compact a tenant's mutable index and hot-swap it under the
        pump without dropping in-flight tickets.

        ``background=True`` runs the rebuild on a worker thread and
        returns a :class:`~repro.serve.engine.Compaction` handle
        immediately — serving continues off the OLD state until the
        hot-swap; a failed rebuild leaves serving untouched and lands in
        ``metrics`` as ``compaction_failed``."""
        name = self._resolve_tenant(tenant)

        def on_done(error):
            self.metrics.count(
                "compaction_failed" if error is not None else "compactions",
                tenant=name)

        try:
            return self.engines[name].compact(background=background,
                                              on_done=on_done)
        except Exception as e:
            # foreground failure raises before Engine calls on_done
            on_done(e)
            raise

    # ---------------------------------------------------------- checkpoints
    def save(self, path):
        """Checkpoint ALL resident tenants into one archive file."""
        return _ckpt.save(path, {t: (e.state, e._ckpt_extra())
                                 for t, e in self.engines.items()})

    @classmethod
    def load(cls, path, *, engine_overrides: Optional[dict] = None,
             **pump_kwargs) -> "AsyncEngine":
        """Restore a multi-tenant archive (or a single-state checkpoint,
        which loads as tenant ``"default"``) into a fresh pump.
        ``engine_overrides`` are per-Engine keyword overrides (e.g.
        ``{"batch_size": 128}``) applied to every tenant."""
        contents = _ckpt.load(path)
        engines = {t: Engine.from_checkpoint_entry(
                       state, extra, **(engine_overrides or {}))
                   for t, (state, extra) in contents.items()}
        return cls(engines, **pump_kwargs)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"AsyncEngine(tenants={list(self.tenants)}, "
                f"max_wait_ms={self.max_wait_s * 1e3:g}, "
                f"max_queue={self.max_queue}, closed={self._closed})")
